//! Precision gates (DESIGN.md §3.11): the `bf16-master-f32` operating
//! point must track the f32 path's numerics within paper-grade bounds
//! and must inherit the f32 path's reproducibility guarantees. All tests
//! run unconditionally on the native engine.
//!
//! Gates pinned here:
//!
//! * **Logit fidelity** — for every builtin config × adapter variant ×
//!   serving path (composed, merged, decode, merged decode), the cosine
//!   between bf16 and f32 final logits exceeds 0.9999, and the logits
//!   actually differ (the gate is not vacuous).
//! * **Loss tracking** — over 200 optimizer steps and 2 seeds, the
//!   per-step |loss_bf16 - loss_f32| stays under 0.5 and its mean under
//!   0.15 (the tiny config's initial loss is ln(64) ≈ 4.16, so these are
//!   generous but far from trivial).
//! * **Determinism** — bf16 runs are bitwise run-to-run reproducible and
//!   bitwise worker-count-invariant: rounding is elementwise on
//!   shape-fixed tensors, so the f64 fixed-order reduction's invariance
//!   carries over unchanged.
//!
//! The f32 side of every comparison is the historical path: its golden
//! fixtures (`tests/golden/`) pin that bf16 support changed nothing.

use std::sync::Arc;

use dorafactors::coordinator::{Trainer, TrainerCfg};
use dorafactors::models::forward;
use dorafactors::numerics::gdist::cosine;
use dorafactors::runtime::ops::AdapterVariant;
use dorafactors::runtime::{
    AdapterParams, BackendSpec, DecodeStepMergedReq, DecodeStepReq, ExecBackend,
    InferMergedReq, InferReq, InitReq, Precision, Tensor, TensorData, Variant,
};
use dorafactors::util::rng::Rng;

const COSINE_GATE: f64 = 0.9999;

/// Seeded init with every layer's B and magnitude pushed off the init
/// point, so the adapter-variant math (rsLoRA rank scaling, BoRA column
/// norms) actually shapes the logits under comparison.
fn perturbed_params(be: &ExecBackend, config: &str, seed: i32) -> AdapterParams {
    let info = be.config(config).unwrap();
    let init = be
        .init(InitReq { config: config.into(), seed, precision: Precision::F32 })
        .unwrap();
    let mut params = init.params;
    let mut rng = Rng::new(seed as u64 ^ 0x9A7E);
    for l in 0..info.n_layers {
        match &mut params.trainable[3 * l + 1].data {
            TensorData::F32(b) => {
                for x in b.iter_mut() {
                    *x = rng.normal() as f32 * 0.1;
                }
            }
            other => panic!("B leaf is not f32: {other:?}"),
        }
        match &mut params.trainable[3 * l + 2].data {
            TensorData::F32(mag) => {
                for x in mag.iter_mut() {
                    *x *= 1.0 + rng.normal() as f32 * 0.05;
                }
            }
            other => panic!("magnitude leaf is not f32: {other:?}"),
        }
    }
    params
}

/// Assert one bf16-vs-f32 logit pair holds the cosine gate and is not
/// bitwise identical (a vacuously passing gate would mean the precision
/// axis silently stopped doing anything).
fn assert_gate(f32_logits: &[f32], bf16_logits: &[f32], label: &str) {
    assert_ne!(
        f32_logits, bf16_logits,
        "{label}: bf16 logits are bitwise f32 — the precision axis is inert"
    );
    let c = cosine(f32_logits, bf16_logits);
    assert!(
        c > COSINE_GATE,
        "{label}: bf16-vs-f32 logit cosine {c:.7} <= {COSINE_GATE}"
    );
}

#[test]
fn bf16_logits_hold_the_cosine_gate_on_every_serving_path() {
    let be = ExecBackend::native();
    for config in ["tiny", "small"] {
        let info = be.config(config).unwrap();
        let params = Arc::new(perturbed_params(&be, config, 11));
        let (bs, seq) = (info.train_batch, info.seq);
        let tokens: Vec<i32> =
            (0..bs * seq).map(|i| ((i * 31 + 7) % info.vocab) as i32).collect();
        let decode_prompt: Vec<i32> =
            (0..6).map(|i| ((i * 13 + 2) % info.vocab) as i32).collect();
        for adapter in AdapterVariant::ALL {
            let label = |path: &str| format!("{config}/{}/{path}", adapter.as_str());

            // Composed batch inference.
            let composed = |precision: Precision| {
                be.infer(InferReq {
                    config: config.into(),
                    variant: Variant::Fused,
                    adapter,
                    precision,
                    params: params.clone(),
                    tokens: Tensor::i32(vec![bs, seq], tokens.clone()),
                })
                .unwrap()
                .logits
                .as_f32()
                .unwrap()
                .to_vec()
            };
            assert_gate(&composed(Precision::F32), &composed(Precision::Bf16), &label("composed"));

            // Merged-weight batch inference (precision rides inside
            // MergedParams, stamped at merge time).
            let merged_infer = |precision: Precision| {
                let merged = Arc::new(
                    forward::merge_adapter_params(&info, &params, adapter, precision).unwrap(),
                );
                be.infer_merged(InferMergedReq {
                    config: config.into(),
                    params: merged,
                    tokens: Tensor::i32(vec![bs, seq], tokens.clone()),
                })
                .unwrap()
                .logits
                .as_f32()
                .unwrap()
                .to_vec()
            };
            assert_gate(
                &merged_infer(Precision::F32),
                &merged_infer(Precision::Bf16),
                &label("merged"),
            );

            // Composed decode: the SAME fixed token sequence at both
            // precisions (logit fidelity is per-step; letting each
            // precision follow its own argmax would compare different
            // inputs).
            let decode = |precision: Precision| {
                let mut all = Vec::new();
                for &t in &decode_prompt {
                    let resp = be
                        .decode_step(DecodeStepReq {
                            config: config.into(),
                            variant: Variant::Fused,
                            adapter,
                            precision,
                            params: params.clone(),
                            tokens: Tensor::i32(vec![1], vec![t]),
                        })
                        .unwrap();
                    all.extend_from_slice(resp.logits.as_f32().unwrap());
                }
                all
            };
            assert_gate(&decode(Precision::F32), &decode(Precision::Bf16), &label("decode"));

            // Merged decode (the steady-state streaming fast path).
            let decode_merged = |precision: Precision| {
                let merged = Arc::new(
                    forward::merge_adapter_params(&info, &params, adapter, precision).unwrap(),
                );
                let mut all = Vec::new();
                for &t in &decode_prompt {
                    let resp = be
                        .decode_step_merged(DecodeStepMergedReq {
                            config: config.into(),
                            params: merged.clone(),
                            tokens: Tensor::i32(vec![1], vec![t]),
                        })
                        .unwrap();
                    all.extend_from_slice(resp.logits.as_f32().unwrap());
                }
                all
            };
            assert_gate(
                &decode_merged(Precision::F32),
                &decode_merged(Precision::Bf16),
                &label("decode-merged"),
            );
        }
    }
}

fn gate_cfg(seed: u64, precision: Precision) -> TrainerCfg {
    TrainerCfg {
        config: "tiny".into(),
        variant: "fused".into(),
        seed,
        branching: 3,
        eval_every: 0,
        train_workers: 0,
        grad_accum: 1,
        precision,
    }
}

#[test]
fn bf16_loss_deltas_stay_bounded_over_200_steps_and_2_seeds() {
    for seed in [5u64, 29] {
        let run = |precision| {
            let mut tr = Trainer::new(
                dorafactors::runtime::NativeEngine::new(),
                gate_cfg(seed, precision),
            )
            .unwrap();
            tr.train_steps(200).unwrap();
            tr.history.iter().map(|r| r.loss).collect::<Vec<f32>>()
        };
        let f = run(Precision::F32);
        let b = run(Precision::Bf16);
        assert_eq!(f.len(), b.len());
        let mut sum = 0f64;
        for (step, (&lf, &lb)) in f.iter().zip(&b).enumerate() {
            assert!(lb.is_finite(), "seed {seed}: bf16 loss diverged at step {}", step + 1);
            let d = (lf as f64 - lb as f64).abs();
            assert!(
                d < 0.5,
                "seed {seed}: step {} loss delta {d:.4} (f32 {lf:.4} vs bf16 {lb:.4})",
                step + 1
            );
            sum += d;
        }
        let mean = sum / f.len() as f64;
        assert!(mean < 0.15, "seed {seed}: mean loss delta {mean:.4} >= 0.15");
        // Both runs must actually learn — a frozen bf16 optimizer would
        // pass a pure delta bound while training nothing.
        let last4 = |t: &[f32]| t[t.len() - 4..].iter().sum::<f32>() / 4.0;
        assert!(last4(&b) < b[0], "seed {seed}: bf16 run never learned");
        assert!(last4(&f) < f[0], "seed {seed}: f32 run never learned");
    }
}

#[test]
fn bf16_training_is_bitwise_reproducible_and_worker_count_invariant() {
    let run = |workers: usize| {
        let cfg = TrainerCfg { train_workers: workers, ..gate_cfg(23, Precision::Bf16) };
        let mut tr = Trainer::with_spec(&BackendSpec::Native, cfg).unwrap();
        tr.train_steps(12).unwrap();
        let losses: Vec<u32> = tr.history.iter().map(|r| r.loss.to_bits()).collect();
        let leaves: Vec<Vec<u32>> = tr
            .trainable()
            .iter()
            .map(|t| t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect())
            .collect();
        (losses, leaves)
    };
    // Run-to-run: two identical single-engine bf16 runs are bitwise equal
    // down to the master leaves.
    let reference = run(0);
    assert_eq!(run(0), reference, "bf16 run-to-run reproducibility broke");
    // Worker-count invariance, including the uneven workers=3 split of
    // the 4-sequence tiny batch: rounding is applied per element of
    // shape-fixed tensors BEFORE the per-sample gradient export, so the
    // fixed-order f64 reduction stays bitwise invariant under bf16.
    for workers in [1usize, 2, 3] {
        assert_eq!(run(workers), reference, "{workers} workers diverged from single-engine");
    }
}
