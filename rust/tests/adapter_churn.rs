//! Adapter-churn chaos tests: sustained load/evict/hot-swap/train-
//! checkpoint-reload churn on a BUDGETED merged-weight cache, under
//! concurrent one-shot and streaming traffic. The native engine is
//! deterministic, so every reply must bitwise-match a quiescent
//! single-adapter reference for its served path — a torn merge, a stale
//! promotion, or a half-swapped entry would produce a third value.
//! All tests run unconditionally on the native engine.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dorafactors::coordinator::{FastPath, GenOptions, Server, ServerCfg, Trainer, TrainerCfg};
use dorafactors::runtime::ops::AdapterParams;
use dorafactors::runtime::{Adapter, AdapterStore, BackendSpec, ExecBackend, InitReq, Precision};

/// Accounted bytes of one tiny-config merge (embed [64, 32] plus two
/// [32, 32] layers = 4096 f32 = 16 KiB, already 512-byte aligned).
const TINY_MERGE_BYTES: u64 = 16 * 1024;

const PROMPT: [i32; 4] = [2, 7, 1, 8];
const STREAM_TOKENS: usize = 12;

fn cfg(workers: usize, fast_path: FastPath, merge_budget: Option<u64>) -> ServerCfg {
    ServerCfg {
        config: "tiny".into(),
        max_wait: Duration::from_millis(2),
        workers,
        fast_path,
        queue_depth: 8,
        merge_budget,
        ..ServerCfg::default()
    }
}

fn tiny_adapter(name: &str, seed: i32) -> Adapter {
    let be = ExecBackend::native();
    let info = be.config("tiny").unwrap();
    let init = be
        .init(InitReq { config: "tiny".into(), seed, precision: Precision::F32 })
        .unwrap();
    Adapter::new(name, &info, seed as u64, 0, init.params).unwrap()
}

/// Unique scratch directory for an adapter-store test, removed on drop.
struct ScratchDir(std::path::PathBuf);

impl ScratchDir {
    fn new(tag: &str) -> ScratchDir {
        let dir =
            std::env::temp_dir().join(format!("dora_churn_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        ScratchDir(dir)
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Quiescent single-adapter references for one parameter set on one fast
/// path: the one-shot logits for [`PROMPT`] and the greedy
/// [`STREAM_TOKENS`]-token decode sequence.
fn references(params: &AdapterParams, path: FastPath) -> (Vec<f32>, Vec<i32>) {
    let server = Server::start_with_params(
        BackendSpec::Native,
        cfg(1, path, None),
        params.frozen.clone(),
        params.trainable.clone(),
    )
    .unwrap();
    let client = server.client();
    let logits = client.infer(&PROMPT).unwrap().logits;
    let tokens = client
        .generate_collect(
            &PROMPT,
            GenOptions { max_tokens: STREAM_TOKENS, ..GenOptions::default() },
        )
        .unwrap();
    drop(client);
    server.shutdown();
    (logits, tokens)
}

#[test]
fn churn_under_traffic_matches_references() {
    // Three versions of the hot adapter: two seeded inits swapped via
    // load_adapter, and one trained checkpoint reloaded from the store
    // via hot_load — the full train -> checkpoint -> serve churn loop.
    let scratch = ScratchDir::new("refs");
    let store = AdapterStore::open(&scratch.0).unwrap();
    let p1 = tiny_adapter("hot", 1).params;
    let p2 = tiny_adapter("hot", 2).params;
    let mut tr = Trainer::with_spec(
        &BackendSpec::Native,
        TrainerCfg {
            config: "tiny".into(),
            variant: "fused".into(),
            seed: 31,
            branching: 3,
            eval_every: 0,
            train_workers: 0,
            grad_accum: 1,
            precision: Precision::F32,
        },
    )
    .unwrap();
    tr.train_steps(8).unwrap();
    let trained = tr.to_adapter("hot").unwrap();
    let p3 = trained.params.clone();
    store.save(&trained).unwrap();

    let fillers: Vec<Adapter> = (0..6).map(|i| tiny_adapter(&format!("f{i}"), 10 + i)).collect();

    // Per-path reference sets, computed on quiescent servers before any
    // churn: the hot adapter may serve any of its three versions, each
    // filler exactly its own parameters.
    let mut hot_logits: BTreeMap<&'static str, Vec<Vec<f32>>> = BTreeMap::new();
    let mut hot_tokens: Vec<Vec<i32>> = Vec::new();
    let mut filler_logits: BTreeMap<(String, &'static str), Vec<f32>> = BTreeMap::new();
    for path in [FastPath::Merged, FastPath::Composed] {
        let mut logits_set = Vec::new();
        for params in [&p1, &p2, &p3] {
            let (logits, tokens) = references(params, path);
            logits_set.push(logits);
            hot_tokens.push(tokens);
        }
        assert_ne!(logits_set[0], logits_set[1], "seeds produced identical logits");
        assert_ne!(logits_set[1], logits_set[2], "training changed nothing");
        hot_logits.insert(path.as_str(), logits_set);
        for f in &fillers {
            let (logits, _) = references(&f.params, path);
            filler_logits.insert((f.name.clone(), path.as_str()), logits);
        }
    }
    let hot_logits = Arc::new(hot_logits);
    let hot_tokens = Arc::new(hot_tokens);
    let filler_logits = Arc::new(filler_logits);

    for pool in [1usize, 2] {
        // Budget for two merges across seven adapters: promotion and
        // eviction run constantly under the traffic below.
        let mut adapters = vec![tiny_adapter("hot", 1)];
        adapters.extend(fillers.iter().cloned());
        let server = Server::start_with_adapters(
            BackendSpec::Native,
            cfg(pool, FastPath::Merged, Some(2 * TINY_MERGE_BYTES)),
            adapters,
        )
        .unwrap();
        let client = server.client();
        let stop = Arc::new(AtomicBool::new(false));

        let names: Vec<String> =
            std::iter::once("hot".to_string()).chain((0..6).map(|i| format!("f{i}"))).collect();
        let hammers: Vec<_> = (0..3)
            .map(|tid: usize| {
                let c = client.clone();
                let stop = stop.clone();
                let names = names.clone();
                let hot_logits = hot_logits.clone();
                let filler_logits = filler_logits.clone();
                std::thread::spawn(move || {
                    let mut served = 0usize;
                    let mut i = tid;
                    while !stop.load(Ordering::SeqCst) {
                        let name = &names[i % names.len()];
                        i += 1;
                        let reply = c.infer_with(name, &PROMPT).unwrap();
                        let path = reply.path.as_str();
                        if name == "hot" {
                            assert!(
                                hot_logits[path].iter().any(|r| *r == reply.logits),
                                "hot reply on {path} path matches no version's reference"
                            );
                        } else {
                            assert_eq!(
                                reply.logits,
                                filler_logits[&(name.clone(), path)],
                                "{name} reply diverged on the {path} path"
                            );
                        }
                        served += 1;
                    }
                    served
                })
            })
            .collect();
        let streamer = {
            let c = client.clone();
            let stop = stop.clone();
            let hot_tokens = hot_tokens.clone();
            std::thread::spawn(move || {
                let mut streams = 0usize;
                while !stop.load(Ordering::SeqCst) {
                    let tokens = c
                        .generate_collect_with(
                            "hot",
                            &PROMPT,
                            GenOptions { max_tokens: STREAM_TOKENS, ..GenOptions::default() },
                        )
                        .unwrap();
                    // The slot snapshots entry and merge at admission, so
                    // the whole sequence must come from ONE (version,
                    // path) pair — a mid-stream flip would splice two
                    // references together.
                    assert!(
                        hot_tokens.iter().any(|r| *r == tokens),
                        "stream matches no (version, path) reference: {tokens:?}"
                    );
                    streams += 1;
                }
                streams
            })
        };

        // Churn driver: swap the hot adapter between its two seeded
        // versions, reloading the trained checkpoint every fourth swap.
        const SWAPS: usize = 24;
        for i in 0..SWAPS {
            if i % 4 == 3 {
                server.hot_load(&store, "hot").unwrap();
            } else if i % 2 == 0 {
                server.load_adapter("hot", p2.clone()).unwrap();
            } else {
                server.load_adapter("hot", p1.clone()).unwrap();
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        stop.store(true, Ordering::SeqCst);
        let served: usize = hammers.into_iter().map(|h| h.join().unwrap()).sum();
        let streams = streamer.join().unwrap();
        assert!(served > 0, "hammer threads never completed a request");
        assert!(streams > 0, "streamer never completed a stream");

        let m = server.shutdown();
        assert_eq!(m.completed, served as u64);
        assert_eq!(m.failed, 0);
        assert_eq!(m.decode_failed, 0);
        assert_eq!(m.hot_loads, SWAPS as u64);
        assert_eq!(m.merge_fallbacks, 0, "an async merge build failed");
        assert!(
            m.cache_evictions > 0,
            "budget never forced an eviction (pool={pool}): {m:?}"
        );
        assert!(m.cache_high_water_bytes <= 2 * TINY_MERGE_BYTES);
        assert!(m.cache_resident <= 2);
    }
}

#[test]
fn shutdown_mid_churn_drains_cleanly() {
    // Shutdown while hammers, a stream, and the async merge builder are
    // all in flight: no panic, no hang, and the books still balance —
    // in-flight requests either complete or surface an error to their
    // caller, never silently vanish.
    let adapters: Vec<Adapter> = (0..4).map(|i| tiny_adapter(&format!("c{i}"), i)).collect();
    let server = Server::start_with_adapters(
        BackendSpec::Native,
        cfg(2, FastPath::Merged, Some(TINY_MERGE_BYTES)),
        adapters,
    )
    .unwrap();
    let client = server.client();
    let hammers: Vec<_> = (0..3)
        .map(|tid: usize| {
            let c = client.clone();
            std::thread::spawn(move || {
                let mut ok = 0usize;
                for i in 0.. {
                    let name = format!("c{}", (tid + i) % 4);
                    match c.infer_with(&name, &PROMPT) {
                        Ok(reply) => {
                            assert!(reply.logit.is_finite());
                            ok += 1;
                        }
                        // The server shut down underneath us: the reply
                        // channel reports it instead of hanging.
                        Err(_) => break,
                    }
                }
                ok
            })
        })
        .collect();
    let streamer = {
        let c = client.clone();
        std::thread::spawn(move || {
            let mut ok = 0usize;
            while c
                .generate_collect_with(
                    "c0",
                    &PROMPT,
                    GenOptions { max_tokens: STREAM_TOKENS, ..GenOptions::default() },
                )
                .is_ok()
            {
                ok += 1;
            }
            ok
        })
    };
    // Let traffic and promotion churn start, swap once, then pull the
    // plug mid-flight.
    let p_new = tiny_adapter("c0", 9).params;
    std::thread::sleep(Duration::from_millis(50));
    server.load_adapter("c0", p_new).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    let m = server.shutdown();

    let served: usize = hammers.into_iter().map(|h| h.join().unwrap()).sum();
    let streams = streamer.join().unwrap();
    assert!(served > 0, "no request completed before shutdown");
    assert!(streams > 0 || m.decode_requests > 0, "streamer never ran");
    assert_eq!(m.failed, 0, "shutdown turned an accepted request into a failure");
    assert!(m.completed >= served as u64);
    assert_eq!(m.hot_loads, 1);
}

#[test]
fn pinned_adapter_survives_cache_squeeze() {
    // A one-merge budget hosting two adapters: the adapter with an
    // active decode stream is pin-exempt from eviction, so the other
    // adapter's promotions are REJECTED (served composed) until the
    // stream's receiver drops and releases the pin.
    let server = Server::start_with_adapters(
        BackendSpec::Native,
        cfg(1, FastPath::Merged, Some(TINY_MERGE_BYTES)),
        vec![tiny_adapter("pin", 1), tiny_adapter("b", 2)],
    )
    .unwrap();
    let client = server.client();
    // An endless unconsumed stream: admission pins "pin" and queues its
    // merge build.
    let stream = client
        .generate_with(
            "pin",
            &PROMPT,
            GenOptions { max_tokens: usize::MAX, ..GenOptions::default() },
        )
        .unwrap();
    let wait_until = |what: &str, cond: &dyn Fn() -> bool| {
        let deadline = Instant::now() + Duration::from_secs(30);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(5));
        }
    };
    wait_until("pin promotion", &|| {
        server.metrics().resident_adapters == vec!["pin".to_string()]
    });
    assert_eq!(server.metrics().cache_pinned, 1);

    // Squeeze: traffic on "b" keeps re-queuing its merge, but promotion
    // cannot evict the pinned resident — every "b" reply stays composed.
    let squeezes = 20usize;
    for _ in 0..squeezes {
        let reply = client.infer_with("b", &PROMPT).unwrap();
        assert_eq!(
            reply.path,
            FastPath::Composed,
            "b was promoted while the budget was pinned"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    let mid = server.metrics();
    assert_eq!(mid.resident_adapters, vec!["pin".to_string()], "pin was evicted");
    assert!(mid.cache_rejects > 0, "the squeeze never attempted a promotion: {mid:?}");
    assert_eq!(mid.cache_evictions, 0);

    // Cancel mid-stream by dropping the receiver: the scheduler retires
    // the slot and releases the pin without any explicit cancel call.
    drop(stream);
    wait_until("stream cancellation", &|| server.metrics().decode_cancelled == 1);
    wait_until("pin release", &|| server.metrics().cache_pinned == 0);

    // Now "b" can take the budget: its next builds evict "pin".
    wait_until("b promotion", &|| {
        client.infer_with("b", &PROMPT).unwrap();
        server.metrics().resident_adapters == vec!["b".to_string()]
    });
    let m = server.shutdown();
    assert!(m.cache_evictions >= 1, "taking the budget never evicted the pin: {m:?}");
    assert!(m.cache_high_water_bytes <= TINY_MERGE_BYTES);
    assert_eq!(m.merge_fallbacks, 0);
}
