//! Parity + determinism suite for the blocked GEMM layer
//! (`kernels::gemm`): every path (blocked, small-K, nt/nn/tn) against a
//! naive f64 reference across adversarial shapes at 1e-6, bitwise
//! equivalence to the branch-free naive f32 loops on every
//! single-k-block shape (which covers all builtin configs), and bitwise
//! determinism across repeated runs and thread counts.

use dorafactors::dora::config::ActShape;
use dorafactors::kernels::gemm::{self, naive, KC, MR, NR, SMALL_K_MAX};
use dorafactors::kernels::{ComposeKernel, ParallelTiledCpu};
use dorafactors::numerics::Dtype;
use dorafactors::util::rng::Rng;

/// Deterministic small-magnitude inputs (std 0.01): keeps the absolute
/// f32-vs-f64 drift of a k-long sequential sum well under the 1e-6 gate
/// even at the deepest test contraction (k > 2·KC).
fn mat(seed: u64, n: usize) -> Vec<f32> {
    Rng::new(seed).normal_vec_f32(n, 0.01)
}

/// f64 reference: C[m,n] = A[m,k] @ B[k,n] with an f64 accumulator.
fn ref_nn_f64(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            c[i * n + j] = acc;
        }
    }
    c
}

fn assert_close_f64(got: &[f32], want: &[f64], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let diff = (g as f64 - w).abs();
        assert!(diff <= 1e-6, "{label} elem {i}: {g} vs {w} (|Δ| = {diff:.3e} > 1e-6)");
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Adversarial (m, k, n) sweep: degenerate dims, rank 1, off-by-one
/// around every tile boundary (MR/NR/SMALL_K_MAX/KC), and the builtin
/// tiny/small/e2e contraction shapes (rows = bs·seq, k ∈ {r, d, vocab}).
fn shapes() -> Vec<(usize, usize, usize)> {
    vec![
        // Degenerate and unit dims.
        (0, 0, 0),
        (0, 3, 4),
        (2, 0, 3),
        (3, 4, 0),
        (1, 1, 1),
        // Rank 1 and single-row/column edges.
        (1, 1, 9),
        (9, 1, 1),
        (63, 1, 65),
        // Tile-boundary ±1 (MR = 4, NR = 8, SMALL_K_MAX = 64).
        (MR - 1, 5, NR - 1),
        (MR + 1, SMALL_K_MAX - 1, NR + 1),
        (2 * MR, SMALL_K_MAX, 2 * NR),
        (17, SMALL_K_MAX + 1, 23),
        // Multi-KC-block contractions (k > 512: reassociated vs naive
        // f32, still within 1e-6 of the f64 reference).
        (6, KC + 137, 10),
        (3, 2 * KC + 76, 11),
        // Builtin tiny (d 32, r 4, rows 64, vocab 64).
        (64, 32, 32),
        (64, 32, 4),
        (64, 4, 32),
        (64, 32, 64),
        // Builtin small (d 64, r 8, rows 256, vocab 256).
        (256, 64, 64),
        (256, 8, 64),
        (256, 64, 256),
        // Builtin e2e (d 128, r 16, rows 512, vocab 512).
        (512, 128, 128),
        (512, 16, 128),
        (512, 128, 512),
    ]
}

#[test]
fn all_paths_match_f64_reference_at_1e6() {
    for (m, k, n) in shapes() {
        let a = mat(11, m * k);
        let b = mat(13, k * n);
        let got = gemm::nn(&a, &b, m, k, n);
        let want = ref_nn_f64(&a, &b, m, k, n);
        assert_close_f64(&got, &want, &format!("nn {m}x{k}x{n}"));

        // nt: B stored [n, k]; reuse the reference by materializing Bᵀ.
        let bt = mat(17, n * k);
        let b_row_major: Vec<f32> =
            (0..k * n).map(|idx| bt[(idx % n) * k + idx / n]).collect();
        let got = gemm::nt(&a, &bt, m, k, n);
        let want = ref_nn_f64(&a, &b_row_major, m, k, n);
        assert_close_f64(&got, &want, &format!("nt {m}x{k}x{n}"));

        // tn: A stored [rows, n1]; C[n1,n2] with contraction depth rows.
        let (rows, n1, n2) = (k, m, n);
        let at = mat(19, rows * n1);
        let bb = mat(23, rows * n2);
        let a_row_major: Vec<f32> =
            (0..n1 * rows).map(|idx| at[(idx % rows) * n1 + idx / rows]).collect();
        let got = gemm::tn(&at, &bb, rows, n1, n2);
        let want = ref_nn_f64(&a_row_major, &bb, n1, rows, n2);
        assert_close_f64(&got, &want, &format!("tn rows={rows} {n1}x{n2}"));
    }
}

#[test]
fn single_k_block_shapes_are_bitwise_naive() {
    // The determinism contract's strong half: for k ≤ KC (every builtin
    // config) the blocked/small-K cores reproduce the branch-free naive
    // loops bit for bit — which is why rerouting the engine through
    // `kernels::gemm` left the committed golden trace untouched.
    for (m, k, n) in shapes() {
        if k > KC {
            continue;
        }
        let a = mat(29, m * k);
        let b = mat(31, k * n);
        assert_eq!(
            bits(&gemm::nn(&a, &b, m, k, n)),
            bits(&naive::nn(&a, &b, m, k, n)),
            "nn {m}x{k}x{n}"
        );
        let bt = mat(37, n * k);
        assert_eq!(
            bits(&gemm::nt(&a, &bt, m, k, n)),
            bits(&naive::nt(&a, &bt, m, k, n)),
            "nt {m}x{k}x{n}"
        );
        let at = mat(41, k * m);
        assert_eq!(
            bits(&gemm::tn(&at, &b, k, m, n)),
            bits(&naive::tn(&at, &b, k, m, n)),
            "tn rows={k} {m}x{n}"
        );
    }
}

#[test]
fn repeated_runs_are_bitwise_identical() {
    // Includes a multi-k-block shape: the reassociated path must still be
    // run-to-run deterministic.
    for (m, k, n) in [(512, 128, 512), (33, KC + 137, 31), (128, 16, 128)] {
        let a = mat(43, m * k);
        let b = mat(47, k * n);
        let bt = mat(53, n * k);
        assert_eq!(bits(&gemm::nn(&a, &b, m, k, n)), bits(&gemm::nn(&a, &b, m, k, n)));
        assert_eq!(bits(&gemm::nt(&a, &bt, m, k, n)), bits(&gemm::nt(&a, &bt, m, k, n)));
        assert_eq!(bits(&gemm::tn(&a, &b, m, k, n)), bits(&gemm::tn(&a, &b, m, k, n)));
    }
}

#[test]
fn thread_count_never_touches_gemm_results() {
    // `DORA_THREADS` sizes the parallel-tiled compose backend (read once
    // into the process-wide registry, so this test constructs the backend
    // with explicit counts — the exact object the env var selects). The
    // GEMM cores themselves are sequential by contract; this pins that
    // the full thread-sensitive kernel stack around them is bitwise
    // invariant for 1, 2 and 4 workers, the in-process counterpart of
    // running the suite under DORA_THREADS ∈ {1,2,4}.
    let act = ActShape::new(512, 128); // e2e rows × d_model
    let n = act.elems();
    let mut rng = Rng::new(61);
    let base = rng.normal_vec_f32(n, 1.0);
    let lora = rng.normal_vec_f32(n, 0.3);
    let g: Vec<f32> = (0..act.d_out).map(|_| 1.0 + rng.normal() as f32 * 0.002).collect();
    let s = 1.7f32;

    let mut reference: Option<(Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>)> = None;
    for threads in [1usize, 2, 4] {
        let be = ParallelTiledCpu::new(threads);
        let (mut delta, mut inner) = (vec![0f32; n], vec![0f32; n]);
        be.forward_dual(&base, &lora, &g, s, act, Dtype::F32, &mut delta, &mut inner);
        let (mut dl, mut db) = (vec![0f32; n], vec![0f32; n]);
        let dmag = be.backward_with_dmag(&delta, &inner, &g, s, act, Dtype::F32, &mut dl, &mut db);
        let got = (bits(&delta), bits(&inner), bits(&dl), bits(&db), bits(&dmag));
        match &reference {
            None => reference = Some(got),
            Some(want) => assert_eq!(want, &got, "{threads} threads diverged from 1 thread"),
        }
    }
}
