//! Serving-pool integration tests: multi-worker dispatch under
//! concurrent multi-adapter load, and the merged-weight hot-swap
//! consistency ("torn weight") guarantee. All tests run unconditionally
//! on the native engine — no artifact gating.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dorafactors::coordinator::{FastPath, Server, ServerCfg};
use dorafactors::runtime::ops::AdapterParams;
use dorafactors::runtime::{Adapter, BackendSpec, ExecBackend, InitReq, Precision};

fn cfg(workers: usize, fast_path: FastPath) -> ServerCfg {
    ServerCfg {
        config: "tiny".into(),
        max_wait: Duration::from_millis(2),
        workers,
        fast_path,
        queue_depth: 8,
        ..ServerCfg::default()
    }
}

fn tiny_adapter(name: &str, seed: i32) -> Adapter {
    let be = ExecBackend::native();
    let info = be.config("tiny").unwrap();
    let init = be
        .init(InitReq { config: "tiny".into(), seed, precision: Precision::F32 })
        .unwrap();
    Adapter::new(name, &info, seed as u64, 0, init.params).unwrap()
}

#[test]
fn four_clients_two_adapters_two_workers_lose_nothing() {
    // Satellite criterion: 4 clients × 2 adapters against a pool of 2
    // workers — no lost or duplicated replies, and the per-adapter
    // metric counts sum to the request count.
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 12;
    let server = Server::start_with_adapters(
        BackendSpec::Native,
        cfg(2, FastPath::Merged),
        vec![tiny_adapter("alice", 1), tiny_adapter("bob", 2)],
    )
    .unwrap();
    let client = server.client();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|cid| {
            let c = client.clone();
            std::thread::spawn(move || {
                let mut replies = Vec::with_capacity(PER_CLIENT);
                for i in 0..PER_CLIENT {
                    let adapter = if (cid + i) % 2 == 0 { "alice" } else { "bob" };
                    // Unique prompt per (client, i): a duplicated or
                    // cross-wired reply would be detectable.
                    let prompt = [cid as i32 + 1, i as i32 % 16, 3];
                    let reply = c.infer_with(adapter, &prompt).unwrap();
                    assert_eq!(reply.adapter, adapter, "reply routed to the wrong adapter");
                    assert!(reply.logit.is_finite());
                    replies.push(reply);
                }
                replies
            })
        })
        .collect();
    let all: Vec<_> = handles
        .into_iter()
        .flat_map(|h| h.join().unwrap())
        .collect();
    // Exactly one reply per request: nothing lost, nothing duplicated.
    assert_eq!(all.len(), CLIENTS * PER_CLIENT);

    let m = server.shutdown();
    let total = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(m.completed, total);
    assert_eq!(m.failed, 0);
    assert_eq!(m.workers, 2);
    // Per-adapter counts sum to the request count (and split evenly —
    // every client alternates).
    let adapter_sum: u64 = m.per_adapter.values().map(|a| a.completed).sum();
    assert_eq!(adapter_sum, total);
    assert_eq!(m.per_adapter["alice"].completed, total / 2);
    assert_eq!(m.per_adapter["bob"].completed, total / 2);
    // Per-worker counts sum to the same totals, and two first-seen
    // adapters spread over both workers.
    assert_eq!(m.per_worker.len(), 2);
    assert_eq!(m.per_worker.iter().map(|w| w.completed).sum::<u64>(), total);
    assert_eq!(m.per_worker.iter().map(|w| w.batches).sum::<u64>(), m.batches);
    assert!(
        m.per_worker.iter().all(|w| w.batches > 0),
        "a pool worker sat idle: {:?}",
        m.per_worker
    );
    // The merged fast path actually served the traffic.
    assert_eq!(m.fast_path, "merged");
    assert_eq!(m.merged_batches, m.batches);
    assert_eq!(m.merge_fallbacks, 0);
}

/// Reference logits for one parameter set through its own single-adapter
/// server (same engine path and padding as the server under test).
fn reference_logits(params: &AdapterParams, prompt: &[i32]) -> Vec<f32> {
    let server = Server::start_with_params(
        BackendSpec::Native,
        cfg(1, FastPath::Merged),
        params.frozen.clone(),
        params.trainable.clone(),
    )
    .unwrap();
    let reply = server.client().infer(prompt).unwrap();
    server.shutdown();
    reply.logits
}

#[test]
fn hot_load_mid_traffic_never_serves_torn_merged_weights() {
    // Satellite criterion: a hot_load under live traffic must never
    // expose a torn merged weight. The native engine is deterministic,
    // so every reply's logits must be bitwise one of the two adapters'
    // reference outputs — a half-swapped parameter/merge pair would
    // produce a third value.
    const PROMPT: [i32; 4] = [2, 7, 1, 8];
    let p1 = tiny_adapter("live", 1).params;
    let p2 = tiny_adapter("live", 2).params;
    let ref1 = reference_logits(&p1, &PROMPT);
    let ref2 = reference_logits(&p2, &PROMPT);
    assert_ne!(ref1, ref2, "seeds produced identical logits");

    let server = Server::start_with_adapters(
        BackendSpec::Native,
        cfg(2, FastPath::Merged),
        vec![tiny_adapter("live", 1)],
    )
    .unwrap();
    let client = server.client();
    let stop = Arc::new(AtomicBool::new(false));
    let hammers: Vec<_> = (0..3)
        .map(|_| {
            let c = client.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while !stop.load(Ordering::SeqCst) {
                    seen.push(c.infer_with("live", &PROMPT).unwrap().logits);
                }
                seen
            })
        })
        .collect();

    // Swap the adapter between the two parameter sets while the hammers
    // run; each load rebuilds the merged weights BEFORE the slot swap.
    const SWAPS: usize = 24;
    for i in 0..SWAPS {
        let params = if i % 2 == 0 { p2.clone() } else { p1.clone() };
        server.load_adapter("live", params).unwrap();
        std::thread::sleep(Duration::from_micros(300));
    }
    stop.store(true, Ordering::SeqCst);

    let mut total = 0usize;
    for h in hammers {
        for logits in h.join().unwrap() {
            total += 1;
            assert!(
                logits == ref1 || logits == ref2,
                "torn merged weights: reply matches neither adapter's reference"
            );
        }
    }
    assert!(total > 0, "hammer threads never completed a request");
    let m = server.shutdown();
    assert_eq!(m.hot_loads, SWAPS as u64);
    assert_eq!(m.failed, 0);
    assert_eq!(m.completed, total as u64);
    assert_eq!(m.merge_fallbacks, 0, "every hot-load must have merged cleanly");
}

#[test]
fn pool_of_four_serves_more_adapters_than_workers() {
    // More adapters than workers: routing wraps around, every request is
    // still answered by the right adapter.
    let adapters: Vec<Adapter> =
        (0..6).map(|i| tiny_adapter(&format!("a{i}"), i)).collect();
    let server =
        Server::start_with_adapters(BackendSpec::Native, cfg(4, FastPath::Merged), adapters)
            .unwrap();
    let client = server.client();
    let mut expected: Vec<(String, Vec<f32>)> = Vec::new();
    for i in 0..6 {
        let name = format!("a{i}");
        let reply = client.infer_with(&name, &[1, 2, 3]).unwrap();
        assert_eq!(reply.adapter, name);
        expected.push((name, reply.logits));
    }
    // Distinct adapters produce distinct logits; repeated queries
    // reproduce them exactly (routing is stable).
    for (name, logits) in &expected {
        let again = client.infer_with(name, &[1, 2, 3]).unwrap();
        assert_eq!(&again.logits, logits, "{name} logits changed across calls");
    }
    for i in 0..6 {
        for j in (i + 1)..6 {
            assert_ne!(expected[i].1, expected[j].1, "a{i} vs a{j}");
        }
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 12);
    assert_eq!(m.workers, 4);
    assert_eq!(m.per_worker.iter().map(|w| w.batches).sum::<u64>(), m.batches);
}
