//! Worker-count invariance of the data-parallel training path.
//!
//! The determinism contract (DESIGN.md §3.7): the shard granularity is
//! one sample, every sample's gradient export is computed from that
//! sample alone, and the reduction accumulates per-sample f32 gradients
//! in f64 IN GLOBAL SAMPLE ORDER — so the reduced gradient, the reduced
//! loss, and everything downstream (AdamW moments, parameters, loss
//! trajectories) are **bitwise-identical for any worker count**,
//! including the adversarial uneven-shard case where
//! `batch % workers != 0`.

use std::sync::Arc;

use dorafactors::coordinator::{Trainer, TrainerCfg};
use dorafactors::runtime::ops::{reduce_sample_grads, AdapterVariant, InitReq, Precision, Variant};
use dorafactors::runtime::{BackendSpec, EnginePool, ExecBackend, GradReducer, Tensor};

fn tiny_cfg(workers: usize, accum: usize) -> TrainerCfg {
    TrainerCfg {
        config: "tiny".into(),
        variant: "fused".into(),
        seed: 41,
        branching: 3,
        eval_every: 0,
        train_workers: workers,
        grad_accum: accum,
        precision: Precision::F32,
    }
}

#[test]
fn reduced_gradients_are_bitwise_identical_across_worker_counts() {
    let be = ExecBackend::native();
    let info = be.config("tiny").unwrap();
    let init = be
        .init(InitReq { config: "tiny".into(), seed: 9, precision: Precision::F32 })
        .unwrap();
    let params = Arc::new(init.params);
    let bs = info.train_batch; // 4: workers=3 is the uneven case (2/1/1)
    let seq1 = info.seq + 1;
    let mut corpus = dorafactors::coordinator::data::MarkovCorpus::new(info.vocab, 3, 77);
    let tokens = Tensor::i32(vec![bs, seq1], corpus.block(1, bs, seq1));
    let total_rows = bs * info.seq;
    let reducer = GradReducer::new("tiny", Variant::Fused, AdapterVariant::Dora, Precision::F32);

    let mut reference: Option<(f32, Vec<Tensor>)> = None;
    for workers in [1usize, 2, 3, 4] {
        let pool = EnginePool::start(&BackendSpec::Native, workers).unwrap();
        let samples = reducer
            .sample_grads(&pool, &params, &tokens, total_rows)
            .unwrap();
        assert_eq!(samples.len(), bs, "{workers} workers");
        let (loss, grads) = reduce_sample_grads(&samples, total_rows).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        match &reference {
            None => reference = Some((loss, grads)),
            Some((l0, g0)) => {
                assert_eq!(
                    loss.to_bits(),
                    l0.to_bits(),
                    "{workers} workers: loss differs from the 1-worker reduction"
                );
                assert_eq!(grads.len(), g0.len());
                for (leaf, (a, b)) in grads.iter().zip(g0).enumerate() {
                    assert!(
                        a.bitwise_eq(b),
                        "{workers} workers: gradient leaf {leaf} is not bitwise-identical"
                    );
                }
            }
        }
    }
}

#[test]
fn adamw_state_is_bitwise_identical_after_ten_steps() {
    // Train 10 optimizer steps per worker count (including the uneven
    // workers=3 split of the 4-sequence tiny batch) and compare the FULL
    // optimizer state — parameters, first/second moments, step counter —
    // plus the loss trajectory, all bitwise.
    let mut reference: Option<(Vec<u32>, Vec<Vec<u32>>)> = None;
    for workers in [1usize, 2, 3, 4] {
        let mut tr = Trainer::with_spec(&BackendSpec::Native, tiny_cfg(workers, 1)).unwrap();
        assert_eq!(tr.train_workers(), workers);
        // tiny chunk = 4 steps; 12 steps >= the 10-step target.
        tr.train_steps(10).unwrap();
        assert_eq!(tr.step_count(), 12);
        let losses: Vec<u32> = tr.history.iter().map(|r| r.loss.to_bits()).collect();
        let leaves: Vec<Vec<u32>> = tr
            .trainable()
            .iter()
            .map(|t| t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect())
            .collect();
        match &reference {
            None => reference = Some((losses, leaves)),
            Some((l0, p0)) => {
                assert_eq!(&losses, l0, "{workers} workers: loss trajectory differs");
                assert_eq!(&leaves, p0, "{workers} workers: parameters differ");
            }
        }
        // The checkpoint records this run's provenance.
        let a = tr.to_adapter(&format!("w{workers}")).unwrap();
        assert_eq!(a.train_workers as usize, workers);
        assert_eq!(a.grad_accum, 1);
    }
}

#[test]
fn variant_trajectories_are_worker_count_invariant_too() {
    // The adapter-variant axis rides the same determinism contract: an
    // rsLoRA run's loss trajectory is bitwise worker-count invariant.
    let mut reference: Option<Vec<u32>> = None;
    for workers in [1usize, 3] {
        let mut tr = Trainer::with_spec(
            &BackendSpec::Native,
            TrainerCfg { variant: "fused-rslora".into(), ..tiny_cfg(workers, 1) },
        )
        .unwrap();
        tr.train_steps(8).unwrap();
        let losses: Vec<u32> = tr.history.iter().map(|r| r.loss.to_bits()).collect();
        match &reference {
            None => reference = Some(losses),
            Some(l0) => assert_eq!(&losses, l0, "{workers} workers (rslora)"),
        }
    }
}

#[test]
fn accumulated_effective_batches_are_worker_count_invariant_too() {
    // grad_accum = 2 across worker counts, covering the accumulation
    // loop's interaction with the reduction order.
    let mut reference: Option<Vec<u32>> = None;
    for workers in [1usize, 3] {
        let mut tr = Trainer::with_spec(&BackendSpec::Native, tiny_cfg(workers, 2)).unwrap();
        tr.train_steps(8).unwrap();
        let losses: Vec<u32> = tr.history.iter().map(|r| r.loss.to_bits()).collect();
        match &reference {
            None => reference = Some(losses),
            Some(l0) => assert_eq!(&losses, l0, "{workers} workers (accum 2)"),
        }
    }
}
