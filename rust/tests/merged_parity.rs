//! Merged-weight vs composed-path parity: property tests over random
//! shapes, ranks, scales, and seeds — including degenerate rows where
//! `rownorm(W + s·B·A)` is (near) zero. Runs unconditionally on the
//! native model (no artifact gating): the merged fast path
//! (`W' = m ⊙ (W + s·B·A) / rownorm(W + s·B·A)`, one matmul per layer)
//! must reproduce the full DoRA composition's logits within 1e-5 f32.

use dorafactors::models::forward::{self, NativeModel};
use dorafactors::runtime::ops::{AdapterParams, AdapterVariant, Precision, Variant};
use dorafactors::runtime::{ConfigInfo, Tensor, TensorData};
use dorafactors::util::prop::{check, prop_close};
use dorafactors::util::rng::Rng;

/// A synthetic config for one random property case (not one of the
/// engine's builtins — shapes are drawn fresh per case).
fn prop_config(
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    seq: usize,
    rank: usize,
    scale: f64,
    train_batch: usize,
) -> ConfigInfo {
    ConfigInfo {
        name: "prop".into(),
        vocab,
        d_model,
        n_layers,
        seq,
        rank,
        scale,
        n_params: 0,
        train_batch,
        chunk_steps: 1,
        frozen: forward::frozen_names(n_layers),
        trainable: forward::trainable_names(n_layers),
    }
}

fn set_f32(t: &mut Tensor, f: impl FnOnce(&mut Vec<f32>)) {
    match &mut t.data {
        TensorData::F32(v) => f(v),
        TensorData::I32(_) => unreachable!("parameter leaves are f32"),
    }
}

#[test]
fn property_merged_logits_match_composed_within_1e5() {
    check("merged == composed logits", 24, |g| {
        let d = g.usize_in(8, 40);
        let r = g.usize_in(1, d.min(8));
        let vocab = g.usize_in(12, 48);
        let seq = g.usize_in(3, 10);
        let n_layers = g.usize_in(1, 3);
        let bs = g.usize_in(1, 4);
        let scale = g.f64_in(0.25, 4.0);
        let info = prop_config(vocab, d, n_layers, seq, r, scale, bs);
        let seed = 1000 + g.case as u64;
        let leaves = forward::init_leaves(&info, seed);
        let mut trainable = leaves.trainable;
        let mut frozen = leaves.frozen;
        // Activate every path: B off zero, magnitudes off the unity
        // point (g != 1), per layer.
        let mut rng = Rng::new(seed ^ 0xB0B);
        for l in 0..n_layers {
            set_f32(&mut trainable[3 * l + 1], |b| {
                for x in b.iter_mut() {
                    *x = rng.normal() as f32 * 0.15;
                }
            });
            let factor = rng.range_f64(0.5, 1.5) as f32;
            set_f32(&mut trainable[3 * l + 2], |mag| {
                for m in mag.iter_mut() {
                    *m *= factor;
                }
            });
        }
        // Degenerate row: W row and B row exactly zero -> rownorm 0,
        // the magnitude division hits its eps clamp on both paths.
        if g.bool() {
            let j = g.usize_in(0, d - 1);
            set_f32(&mut frozen[1], |w| {
                w[j * d..(j + 1) * d].fill(0.0);
            });
            set_f32(&mut trainable[1], |b| {
                b[j * r..(j + 1) * r].fill(0.0);
            });
        }
        // Near-degenerate row: rownorm ~ 1e-18, far below the 1e-12 eps
        // clamp, so g explodes to ~1e12 on both paths.
        if g.bool() {
            let j = g.usize_in(0, d - 1);
            set_f32(&mut frozen[1], |w| {
                for x in &mut w[j * d..(j + 1) * d] {
                    *x *= 1e-18;
                }
            });
            set_f32(&mut trainable[1], |b| {
                for x in &mut b[j * r..(j + 1) * r] {
                    *x *= 1e-18;
                }
            });
        }
        let params = AdapterParams { frozen, trainable };
        let tokens: Vec<i32> =
            (0..bs * seq).map(|_| g.usize_in(0, vocab - 1) as i32).collect();

        let kernels = forward::kernels_for(Variant::Fused, &info, false)
            .map_err(|e| format!("kernels: {e:#}"))?;
        let model = NativeModel::new(&info, &params.frozen, &params.trainable, kernels)
            .map_err(|e| format!("model: {e:#}"))?;
        let composed = model
            .infer_logits(&tokens, bs, seq)
            .map_err(|e| format!("composed infer: {e:#}"))?;
        let merged =
            forward::merge_adapter_params(&info, &params, AdapterVariant::Dora, Precision::F32)
                .map_err(|e| format!("merge: {e:#}"))?;
        let fast = forward::merged_infer_logits(&info, &merged, &tokens, bs, seq)
            .map_err(|e| format!("merged infer: {e:#}"))?;

        for i in 0..bs * vocab {
            prop_close(
                composed[i] as f64,
                fast[i] as f64,
                1e-5,
                &format!("logit {i} (d={d} r={r} layers={n_layers} scale={scale:.3})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn property_variant_merges_match_their_composed_paths() {
    // The adapter-variant merge formulas (rsLoRA's rank-stabilized scale,
    // BoRA's frozen column gain) against their composed forwards, over
    // random shapes — the factored column-norm path included.
    check("variant merged == composed logits", 12, |g| {
        let d = g.usize_in(8, 36);
        let r = g.usize_in(1, d.min(6));
        let vocab = g.usize_in(12, 32);
        let seq = g.usize_in(3, 8);
        let n_layers = g.usize_in(1, 2);
        let bs = g.usize_in(1, 3);
        let scale = g.f64_in(0.25, 3.0);
        let info = prop_config(vocab, d, n_layers, seq, r, scale, bs);
        let seed = 4000 + g.case as u64;
        let leaves = forward::init_leaves(&info, seed);
        let mut trainable = leaves.trainable;
        let mut rng = Rng::new(seed ^ 0x5CA1E);
        for l in 0..n_layers {
            set_f32(&mut trainable[3 * l + 1], |b| {
                for x in b.iter_mut() {
                    *x = rng.normal() as f32 * 0.12;
                }
            });
        }
        let params = AdapterParams { frozen: leaves.frozen, trainable };
        let tokens: Vec<i32> =
            (0..bs * seq).map(|_| g.usize_in(0, vocab - 1) as i32).collect();
        for adapter in [AdapterVariant::RsLora, AdapterVariant::Bora] {
            let kernels = forward::kernels_for(Variant::Fused, &info, false)
                .map_err(|e| format!("kernels: {e:#}"))?;
            let model = NativeModel::new(&info, &params.frozen, &params.trainable, kernels)
                .map_err(|e| format!("model: {e:#}"))?
                .with_adapter(adapter);
            let composed = model
                .infer_logits(&tokens, bs, seq)
                .map_err(|e| format!("composed infer: {e:#}"))?;
            let merged = forward::merge_adapter_params(&info, &params, adapter, Precision::F32)
                .map_err(|e| format!("merge: {e:#}"))?;
            let fast = forward::merged_infer_logits(&info, &merged, &tokens, bs, seq)
                .map_err(|e| format!("merged infer: {e:#}"))?;
            for i in 0..bs * vocab {
                prop_close(
                    composed[i] as f64,
                    fast[i] as f64,
                    1e-5,
                    &format!("{adapter:?} logit {i} (d={d} r={r} scale={scale:.3})"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn property_merged_parity_holds_for_eager_variant_too() {
    // The merged weights are variant-independent (the merge IS the math);
    // the eager compose path must agree with them just as well.
    check("merged == eager-composed logits", 10, |g| {
        let d = g.usize_in(8, 32);
        let r = g.usize_in(1, 6);
        let info = prop_config(16, d, 2, 6, r, g.f64_in(0.5, 3.0), 2);
        let seed = 7000 + g.case as u64;
        let leaves = forward::init_leaves(&info, seed);
        let mut trainable = leaves.trainable;
        let mut rng = Rng::new(seed ^ 0xEA6E);
        for l in 0..2 {
            set_f32(&mut trainable[3 * l + 1], |b| {
                for x in b.iter_mut() {
                    *x = rng.normal() as f32 * 0.1;
                }
            });
        }
        let params = AdapterParams { frozen: leaves.frozen, trainable };
        let tokens: Vec<i32> = (0..2 * 6).map(|_| g.usize_in(0, 15) as i32).collect();
        let kernels = forward::kernels_for(Variant::Eager, &info, false)
            .map_err(|e| format!("kernels: {e:#}"))?;
        let model = NativeModel::new(&info, &params.frozen, &params.trainable, kernels)
            .map_err(|e| format!("model: {e:#}"))?;
        let composed = model
            .infer_logits(&tokens, 2, 6)
            .map_err(|e| format!("composed infer: {e:#}"))?;
        let merged =
            forward::merge_adapter_params(&info, &params, AdapterVariant::Dora, Precision::F32)
                .map_err(|e| format!("merge: {e:#}"))?;
        let fast = forward::merged_infer_logits(&info, &merged, &tokens, 2, 6)
            .map_err(|e| format!("merged infer: {e:#}"))?;
        for i in 0..composed.len() {
            prop_close(composed[i] as f64, fast[i] as f64, 1e-5, &format!("logit {i}"))?;
        }
        Ok(())
    });
}
