//! REAL-measurement bench: L3 hot-path overheads — dispatch decision
//! latency, allocator-simulator replay throughput, native-engine
//! serve/train latency + batch occupancy, and PJRT executable invocation
//! latency for the compose artifacts (eager vs fused).
//!
//! The paper's L3 target (PERFORMANCE OPTIMIZATION §L3): the coordinator
//! must never be the bottleneck — dispatch < 1 us/module, engine dispatch
//! overhead small relative to kernel time. The native rows run on every
//! machine (no artifacts needed), so the serving stack always produces
//! real numbers.

use std::time::Duration;

use dorafactors::bench::timing;
use dorafactors::coordinator::{FastPath, Server, ServerCfg, Trainer, TrainerCfg};
use dorafactors::dispatch::{self, ComposeCtx, DispatchEnv};
use dorafactors::dora::config::{ActShape, Config, ModuleShape};
use dorafactors::dora::mem_events;
use dorafactors::memsim::allocator::CachingAllocator;
use dorafactors::numerics::Dtype;
use dorafactors::runtime::{
    manifest, Adapter, BackendSpec, Engine, ExecBackend, InitReq, NativeEngine, Precision, Tensor,
};
use dorafactors::util::rng::Rng;
use dorafactors::util::table::{fmt_secs, Table};

fn main() {
    let cfg = timing::BenchCfg { warmup: 3, trials: 50, time_cap_s: 10.0 };
    let mut t = Table::new("L3 hot-path overheads (REAL)", &["operation", "median", "per unit"]);

    // Dispatch: full model inventory (252 modules for Qwen3-VL-8B).
    let env = DispatchEnv::default();
    let spec = dorafactors::models::find("Qwen3-VL-8B").unwrap();
    let inv = spec.inventory(384);
    let m = timing::bench("dispatch", cfg, || {
        for (_, shape, count) in &inv {
            for _ in 0..*count {
                std::hint::black_box(dispatch::select_tier(
                    &env,
                    &ComposeCtx::training(ActShape::new(4096, shape.d_out)),
                ));
            }
        }
    });
    let n_mod = spec.n_adapted_modules();
    t.row(vec![
        format!("dispatch x{n_mod} modules"),
        fmt_secs(m.median_s),
        format!("{:.1} ns/module", m.median_s / n_mod as f64 * 1e9),
    ]);
    assert!(
        (m.median_s / n_mod as f64) < 1e-6,
        "dispatch exceeds 1 us/module"
    );

    // Registry dispatch: tier decision + backend handle (Arc clone) — the
    // kernel-backend layer's hot-path surface.
    let m = timing::bench("select_kernel", cfg, || {
        for (_, shape, count) in &inv {
            for _ in 0..*count {
                std::hint::black_box(dispatch::select_kernel(
                    &env,
                    &ComposeCtx::training(ActShape::new(4096, shape.d_out)),
                ));
            }
        }
    });
    t.row(vec![
        format!("select_kernel x{n_mod} modules"),
        fmt_secs(m.median_s),
        format!("{:.1} ns/module", m.median_s / n_mod as f64 * 1e9),
    ]);

    // Allocator replay: one full model's norm event streams.
    let shape = ModuleShape::new(4096, 4096, 384);
    let events = mem_events::norm_events(shape, Config::Eager, Dtype::Bf16, 256 << 20);
    let m = timing::bench("memsim replay", cfg, || {
        let mut a = CachingAllocator::new();
        a.replay(std::hint::black_box(&events));
        std::hint::black_box(a.max_allocated());
    });
    t.row(vec![
        format!("allocator replay ({} events)", events.len()),
        fmt_secs(m.median_s),
        format!("{:.0} ns/event", m.median_s / events.len() as f64 * 1e9),
    ]);

    // Native engine: one full training chunk (forward + backward + AdamW
    // for chunk_steps optimizer steps) on the tiny config.
    {
        let mut tr = Trainer::new(
            NativeEngine::new(),
            TrainerCfg {
                config: "tiny".into(),
                variant: "fused".into(),
                seed: 0,
                branching: 4,
                eval_every: 0,
                train_workers: 0,
                grad_accum: 1,
                precision: Precision::F32,
            },
        )
        .expect("native trainer");
        let chunk_steps = tr.config_info().chunk_steps;
        let quick = timing::BenchCfg { warmup: 1, trials: 10, time_cap_s: 10.0 };
        let m = timing::bench("native train chunk", quick, || {
            tr.run_chunk().unwrap();
        });
        t.row(vec![
            "native train chunk (tiny)".into(),
            fmt_secs(m.median_s),
            format!("{:.2} ms/step", m.median_s / chunk_steps as f64 * 1e3),
        ]);
    }

    // Data-parallel train scaling: workers {1, 2, 4} x grad-accum {1, 4}
    // on the tiny config. Each row is one chunk (chunk_steps optimizer
    // steps); with accum K a step processes K micro-batches, so the
    // tokens/s column is the comparable throughput number. The reduced
    // gradient is bitwise-identical across worker counts (the golden
    // trace pins it), so these rows measure pure scheduling overhead vs
    // overlap.
    for workers in [1usize, 2, 4] {
        for accum in [1usize, 4] {
            let mut tr = Trainer::with_spec(
                &BackendSpec::Native,
                TrainerCfg {
                    config: "tiny".into(),
                    variant: "fused".into(),
                    seed: 0,
                    branching: 4,
                    eval_every: 0,
                    train_workers: workers,
                    grad_accum: accum,
                    precision: Precision::F32,
                },
            )
            .expect("data-parallel trainer");
            let info = tr.config_info();
            let chunk_steps = info.chunk_steps;
            let tokens_per_chunk = chunk_steps * accum * info.train_batch * (info.seq + 1);
            let quick = timing::BenchCfg { warmup: 1, trials: 8, time_cap_s: 8.0 };
            let m = timing::bench("dp train chunk", quick, || {
                tr.run_chunk().unwrap();
            });
            assert!(tr.history.iter().all(|r| r.loss.is_finite() && r.loss > 0.0));
            t.row(vec![
                format!("dp train chunk (tiny, workers={workers}, accum={accum})"),
                fmt_secs(m.median_s),
                format!(
                    "{:.2} ms/step, {:.0} tok/s",
                    m.median_s / chunk_steps as f64 * 1e3,
                    tokens_per_chunk as f64 / m.median_s
                ),
            ]);
        }
    }

    // Native engine: single-request serving round-trip (client -> batcher
    // -> infer -> reply), and a measured batch-occupancy sweep.
    {
        let server = Server::start(
            BackendSpec::Native,
            ServerCfg {
                config: "tiny".into(),
                max_wait: Duration::from_millis(1),
                workers: 1,
                fast_path: FastPath::Composed,
                queue_depth: 32,
                ..ServerCfg::default()
            },
        )
        .expect("native server");
        let client = server.client();
        let quick = timing::BenchCfg { warmup: 2, trials: 30, time_cap_s: 10.0 };
        let m = timing::bench("native serve rtt", quick, || {
            client.infer(&[1, 2, 3, 4]).unwrap();
        });
        t.row(vec![
            "native serve round-trip (tiny, bs occupancy 1)".into(),
            fmt_secs(m.median_s),
            format!("{:.0} req/s", 1.0 / m.median_s),
        ]);
        drop(client);
        let metrics = server.shutdown();
        // Concurrent clients: measure how well batch-or-timeout packs.
        let server = Server::start(
            BackendSpec::Native,
            ServerCfg {
                config: "tiny".into(),
                max_wait: Duration::from_millis(20),
                workers: 1,
                fast_path: FastPath::Composed,
                queue_depth: 32,
                ..ServerCfg::default()
            },
        )
        .expect("native server");
        let client = server.client();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || {
                    for _ in 0..8 {
                        c.infer(&[i as i32 + 1, 2, 3]).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let m2 = server.shutdown();
        let bs_cap = NativeEngine::new().config("tiny").expect("tiny config").train_batch;
        t.row(vec![
            format!("native batched serve (8 clients x 8 req, {} batches)", m2.batches),
            format!("p95 {}", fmt_secs(m2.p95_us() / 1e6)),
            format!("mean occupancy {:.2}/{bs_cap}", m2.mean_occupancy()),
        ]);
        assert!(metrics.completed > 0);
        assert!(m2.completed == 64, "completed {}", m2.completed);
    }

    // Multi-adapter serving: the same 8x8 concurrent load, but spread
    // round-robin over 2 and 4 hosted adapters — what per-adapter request
    // grouping costs relative to the single-adapter row above (each
    // distinct adapter in a collected batch is one more engine call).
    for n_adapters in [2usize, 4] {
        let be = ExecBackend::native();
        let info = be.config("tiny").expect("tiny config");
        let adapters: Vec<Adapter> = (0..n_adapters)
            .map(|i| {
                let init = be
                    .init(InitReq {
                        config: "tiny".into(),
                        seed: i as i32,
                        precision: Precision::F32,
                    })
                    .expect("init");
                Adapter::new(format!("adapter-{i}"), &info, i as u64, 0, init.params)
                    .expect("adapter")
            })
            .collect();
        let server = Server::start_with_adapters(
            BackendSpec::Native,
            ServerCfg {
                config: "tiny".into(),
                max_wait: Duration::from_millis(20),
                workers: 1,
                fast_path: FastPath::Composed,
                queue_depth: 32,
                ..ServerCfg::default()
            },
            adapters,
        )
        .expect("multi-adapter server");
        let client = server.client();
        let handles: Vec<_> = (0..8)
            .map(|cid| {
                let c = client.clone();
                std::thread::spawn(move || {
                    for i in 0..8usize {
                        let adapter = format!("adapter-{}", (cid + i) % n_adapters);
                        c.infer_with(&adapter, &[cid as i32 + 1, 2, 3]).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 64, "completed {}", m.completed);
        assert_eq!(m.per_adapter.len(), n_adapters);
        for (name, am) in &m.per_adapter {
            assert_eq!(am.completed, 64 / n_adapters as u64, "{name}");
        }
        t.row(vec![
            format!(
                "native multi-adapter serve ({n_adapters} adapters, 8 clients x 8 req, {} engine calls)",
                m.batches
            ),
            format!("p95 {}", fmt_secs(m.p95_us() / 1e6)),
            format!("mean occupancy {:.2}", m.mean_occupancy()),
        ]);
    }

    // Serving pool × fast path: pool sizes {1, 2, 4} × {merged, composed}
    // on the bench's default serving shape (the `small` config). The
    // single-request rows isolate per-request latency (max_wait = 0, no
    // batching window); the concurrent rows drive 2 adapters from 4
    // clients so pool > 1 actually overlaps engine calls. Acceptance
    // criterion checked below: merged beats composed at pool size 1.
    let mut pool_medians: Vec<((usize, &str), f64)> = Vec::new();
    for pool in [1usize, 2, 4] {
        for fast_path in [FastPath::Merged, FastPath::Composed] {
            let server = Server::start(
                BackendSpec::Native,
                ServerCfg {
                    config: "small".into(),
                    max_wait: Duration::ZERO,
                    workers: pool,
                    fast_path,
                    queue_depth: 32,
                    ..ServerCfg::default()
                },
            )
            .expect("pool server");
            let client = server.client();
            let quick = timing::BenchCfg { warmup: 3, trials: 40, time_cap_s: 10.0 };
            let m = timing::bench("pool serve rtt", quick, || {
                client.infer(&[1, 2, 3, 4]).unwrap();
            });
            drop(client);
            let sm = server.shutdown();
            assert_eq!(sm.fast_path, fast_path.as_str(), "requested path not effective");
            if fast_path == FastPath::Merged {
                assert!(sm.merged_batches > 0, "merged path never executed");
            } else {
                assert_eq!(sm.merged_batches, 0);
            }
            pool_medians.push(((pool, fast_path.as_str()), m.median_s));
            t.row(vec![
                format!("native pool serve (small, pool={pool}, path={})", sm.fast_path),
                fmt_secs(m.median_s),
                format!("{:.0} req/s", 1.0 / m.median_s),
            ]);
        }
    }
    let median_of = |pool: usize, path: &str| -> f64 {
        pool_medians
            .iter()
            .find(|((p, fp), _)| *p == pool && *fp == path)
            .map(|(_, v)| *v)
            .expect("pool median recorded")
    };
    let (merged1, composed1) = (median_of(1, "merged"), median_of(1, "composed"));
    assert!(
        merged1 < composed1,
        "merged fast path not faster at pool=1: merged {merged1:.3e}s vs composed {composed1:.3e}s"
    );
    t.row(vec![
        "merged speedup at pool=1".into(),
        format!("{:.2}x", composed1 / merged1),
        "merged vs composed per-request".into(),
    ]);

    // Pool scaling under concurrent multi-adapter load: 4 clients × 2
    // adapters hammering the pool (merged path), per pool size.
    for pool in [1usize, 2, 4] {
        let be = ExecBackend::native();
        let info = be.config("small").expect("small config");
        let adapters: Vec<Adapter> = (0..2)
            .map(|i| {
                let init = be
                    .init(InitReq {
                        config: "small".into(),
                        seed: 100 + i as i32,
                        precision: Precision::F32,
                    })
                    .expect("init");
                Adapter::new(format!("pool-adapter-{i}"), &info, i as u64, 0, init.params)
                    .expect("adapter")
            })
            .collect();
        let server = Server::start_with_adapters(
            BackendSpec::Native,
            ServerCfg {
                config: "small".into(),
                max_wait: Duration::from_millis(2),
                workers: pool,
                fast_path: FastPath::Merged,
                queue_depth: 32,
                ..ServerCfg::default()
            },
            adapters,
        )
        .expect("pool server");
        let client = server.client();
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|cid| {
                let c = client.clone();
                std::thread::spawn(move || {
                    for i in 0..16usize {
                        let adapter = format!("pool-adapter-{}", (cid + i) % 2);
                        c.infer_with(&adapter, &[cid as i32 + 1, 2, 3]).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = server.shutdown();
        assert_eq!(m.completed, 64, "completed {}", m.completed);
        assert_eq!(
            m.per_worker.iter().map(|w| w.batches).sum::<u64>(),
            m.batches,
            "per-worker batches don't sum"
        );
        t.row(vec![
            format!(
                "native pool serve concurrent (small, pool={pool}, 2 adapters, {} engine calls)",
                m.batches
            ),
            format!("{:.0} req/s", m.completed as f64 / wall),
            format!("p95 {}", fmt_secs(m.p95_us() / 1e6)),
        ]);
    }

    // PJRT invocation: compose artifacts, eager vs fused lowering.
    let dir = manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let engine = Engine::load(&dir).expect("engine");
        let (rows, d_out) = (512usize, 2048usize);
        let mut rng = Rng::new(1);
        let inputs = [
            Tensor::f32(vec![rows, d_out], rng.normal_vec_f32(rows * d_out, 1.0)),
            Tensor::f32(vec![rows, d_out], rng.normal_vec_f32(rows * d_out, 0.3)),
            Tensor::f32(vec![d_out], rng.normal_vec_f32(d_out, 0.01)),
        ];
        for name in ["compose_eager_512x2048", "compose_fused_512x2048"] {
            engine.executable(name).unwrap(); // warm compile
            let m = timing::bench(name, cfg, || {
                std::hint::black_box(engine.run(name, &inputs).unwrap());
            });
            t.row(vec![
                format!("PJRT {name}"),
                fmt_secs(m.median_s),
                format!(
                    "{:.2} GB/s effective",
                    (4 * rows * d_out * 4) as f64 / m.median_s / 1e9
                ),
            ]);
        }
    } else {
        eprintln!("(artifacts missing — run `make artifacts` for the PJRT rows)");
    }

    println!("{}", t.to_markdown());
}
