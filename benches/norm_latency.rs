//! REAL-measurement bench: the weight-norm engines on CPU, with measured
//! transient memory (Figure 10's latency tradeoff + Table 1/7's
//! measured-memory methodology, at CPU-feasible scales). The factored
//! engines run through the kernel-backend layer's `NormEngine` trait
//! (sequential + parallel-tiled backends).
//!
//! Expected shape of the results (the paper's claims):
//! * factored uses orders of magnitude less transient memory;
//! * the dense engines pay the materialization; the factored path's
//!   latency is dominated by the U contraction (rank-dependent).

use dorafactors::bench::{shapes, timing};
use dorafactors::dora::norm_cpu::{self, AllocTracker};
use dorafactors::kernels::{FusedCpu, NormEngine, ParallelTiledCpu};
use dorafactors::numerics::Dtype;
use dorafactors::util::rng::Rng;
use dorafactors::util::table::{fmt_bytes, fmt_secs, Table};

fn main() {
    let cfg = timing::BenchCfg { warmup: 1, trials: 10, time_cap_s: 20.0 };
    let dt = Dtype::F32;
    let seq_engine: &dyn NormEngine = &FusedCpu;
    let par_engine = ParallelTiledCpu::new(4);
    let mut t = Table::new(
        "weight-norm engines (REAL CPU): latency + measured transient peak",
        &[
            "shape",
            "r",
            "peft",
            "dense",
            "factored",
            "par-tiled",
            "peft mem",
            "dense mem",
            "fact mem",
            "mem x",
        ],
    );
    for m in shapes::cpu_norm_shapes() {
        let mut rng = Rng::new(m.rank as u64);
        let w = rng.normal_vec_f32(m.d_out * m.d_in, 0.05);
        let a = rng.normal_vec_f32(m.rank * m.d_in, 0.1);
        let b = rng.normal_vec_f32(m.d_out * m.rank, 0.1);
        let s = 1.5f32;
        let budget = norm_cpu::DEFAULT_CHUNK_BUDGET;

        let mut tp = AllocTracker::new();
        let peft = timing::bench("peft", cfg, || {
            let mut tr = AllocTracker::new();
            std::hint::black_box(norm_cpu::peft_norm(&w, &a, &b, s, m, &mut tr));
        });
        norm_cpu::peft_norm(&w, &a, &b, s, m, &mut tp);

        let mut td = AllocTracker::new();
        let dense = timing::bench("dense", cfg, || {
            let mut tr = AllocTracker::new();
            std::hint::black_box(norm_cpu::dense_ba_norm(&w, &a, &b, s, m, &mut tr));
        });
        norm_cpu::dense_ba_norm(&w, &a, &b, s, m, &mut td);

        let mut tf = AllocTracker::new();
        let fact = timing::bench("factored", cfg, || {
            let mut tr = AllocTracker::new();
            std::hint::black_box(seq_engine.weight_norm(&w, &a, &b, s, m, budget, dt, &mut tr));
        });
        seq_engine.weight_norm(&w, &a, &b, s, m, budget, dt, &mut tf);

        let par = timing::bench("par-tiled", cfg, || {
            let mut tr = AllocTracker::new();
            std::hint::black_box(par_engine.weight_norm(&w, &a, &b, s, m, budget, dt, &mut tr));
        });

        t.row(vec![
            format!("{}x{}", m.d_out, m.d_in),
            m.rank.to_string(),
            fmt_secs(peft.median_s),
            fmt_secs(dense.median_s),
            fmt_secs(fact.median_s),
            fmt_secs(par.median_s),
            fmt_bytes(tp.peak()),
            fmt_bytes(td.peak()),
            fmt_bytes(tf.peak()),
            format!("{:.1}x", tp.peak() as f64 / tf.peak() as f64),
        ]);
        // Invariant mirrored from the paper: the factored path's
        // transient memory is strictly smaller. Latency is NOT asserted —
        // the paper itself reports the factored norm 4.8x slower than the
        // dense reference in isolation (§2.3 compute tradeoff); what wins
        // end-to-end is avoiding the materialization, not the norm op.
        assert!(tf.peak() < td.peak(), "factored must use less transient memory");
    }
    println!("{}", t.to_markdown());
    println!("(paper Table 7 measured reductions at datacenter scales: 2.6-11x)");
}
