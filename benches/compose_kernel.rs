//! REAL-measurement bench: the fused-vs-eager compose on CPU
//! (regenerates the *mechanism* behind Figure 6 / Table 9's compose
//! column — see DESIGN.md §1 on what transfers from the simulator).
//!
//! Both paths run in the caching-allocator regime (preallocated, reused
//! buffers — exactly PyTorch's steady state), so the measurement isolates
//! PASS COUNT: eager makes 4 separate passes through 9 array-streams,
//! fused one pass through 3. Past LLC both are memory-bound, so the
//! speedup and its growth with working-set size are real measurements.

use dorafactors::bench::{shapes, timing};
use dorafactors::dora::compose_cpu;
use dorafactors::util::stats;
use dorafactors::util::table::{fmt_secs, fmt_speedup, Table};
use dorafactors::util::rng::Rng;

fn main() {
    let cfg = timing::BenchCfg { warmup: 3, trials: 30, time_cap_s: 15.0 };
    let mut t = Table::new(
        "compose kernel (REAL CPU): eager 4-pass vs fused 1-pass",
        &["rows x d_out", "MiB", "eager", "fused", "dual", "speedup", "fused GB/s"],
    );
    let mut speedups = Vec::new();
    for act in shapes::cpu_act_shapes() {
        let mut rng = Rng::new(act.rows as u64);
        let base = rng.normal_vec_f32(act.elems(), 1.0);
        let lora = rng.normal_vec_f32(act.elems(), 0.3);
        let g: Vec<f32> = (0..act.d_out)
            .map(|_| 1.0 + rng.normal() as f32 * 0.002)
            .collect();
        let s = 2.0f32;

        let mut temps = compose_cpu::EagerTemps::new(act);
        let mut out = vec![0f32; act.elems()];
        let eager = timing::bench("eager", cfg, || {
            compose_cpu::compose_eager_into(&base, &lora, &g, s, act, &mut temps, &mut out);
            std::hint::black_box(&out);
        });
        let fused = timing::bench("fused", cfg, || {
            compose_cpu::compose_fused_into(&base, &lora, &g, s, act, &mut out);
            std::hint::black_box(&out);
        });
        let mut inner = vec![0f32; act.elems()];
        let dual = timing::bench("dual", cfg, || {
            compose_cpu::compose_fused_dual_into(&base, &lora, &g, s, act, &mut out, &mut inner);
            std::hint::black_box((&out, &inner));
        });
        let speedup = eager.median_s / fused.median_s;
        speedups.push(speedup);
        // Useful traffic of the fused pass: 3 reads + 1 write, f32.
        let bytes = (4 * act.elems() * 4) as u64;
        t.row(vec![
            format!("{}x{}", act.rows, act.d_out),
            format!("{:.0}", (act.elems() * 4) as f64 / (1 << 20) as f64),
            fmt_secs(eager.median_s),
            fmt_secs(fused.median_s),
            fmt_secs(dual.median_s),
            fmt_speedup(speedup),
            format!("{:.1}", fused.throughput_gbps(bytes)),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "geomean speedup: {} (paper compose-fwd geomeans: 1.47-2.70x across GPUs)",
        fmt_speedup(stats::geomean(&speedups))
    );
    assert!(
        stats::geomean(&speedups) > 1.2,
        "fused compose should beat the 4-pass chain on CPU"
    );
    // The mechanism check: fused wins at every shape.
    assert!(
        speedups.iter().all(|&s| s > 1.1),
        "fused lost somewhere: {speedups:?}"
    );
}
