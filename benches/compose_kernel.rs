//! REAL-measurement bench: the fused-vs-eager compose on CPU
//! (regenerates the *mechanism* behind Figure 6 / Table 9's compose
//! column — see DESIGN.md §1 on what transfers from the simulator), plus
//! the `ParallelTiledCpu` thread-scaling sweep of the kernel-backend
//! layer.
//!
//! Both sequential paths run in the caching-allocator regime
//! (preallocated, reused buffers — exactly PyTorch's steady state), so the
//! measurement isolates PASS COUNT: eager makes 4 separate passes through
//! 9 array-streams, fused one pass through 3. Past LLC both are
//! memory-bound, so the speedup and its growth with working-set size are
//! real measurements. The parallel backend then shows the multi-core
//! headroom on LLC-exceeding shapes.
//!
//! Results are also emitted as JSON (`bench_results/compose_kernel.json`,
//! or `$DORA_BENCH_JSON`) so the perf trajectory is machine-readable.

use dorafactors::bench::{shapes, timing};
use dorafactors::dora::compose_cpu;
use dorafactors::kernels::{ComposeKernel, ParallelTiledCpu};
use dorafactors::numerics::Dtype;
use dorafactors::util::json::Json;
use dorafactors::util::rng::Rng;
use dorafactors::util::stats;
use dorafactors::util::table::{fmt_secs, fmt_speedup, Table};

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let cfg = timing::BenchCfg { warmup: 3, trials: 30, time_cap_s: 15.0 };
    let mut t = Table::new(
        "compose kernel (REAL CPU): eager 4-pass vs fused 1-pass",
        &["rows x d_out", "MiB", "eager", "fused", "dual", "speedup", "fused GB/s"],
    );
    let mut scaling = Table::new(
        "ParallelTiledCpu thread scaling (vs fused single-pass = 1.00x)",
        &["rows x d_out", "t=1", "t=2", "t=4", "t=8", "best GB/s"],
    );
    let mut speedups = Vec::new();
    let mut json_shapes: Vec<Json> = Vec::new();
    let mut json_scaling: Vec<Json> = Vec::new();
    let mut llc_exceeding_t4 = Vec::new();

    for act in shapes::cpu_act_shapes() {
        let mut rng = Rng::new(act.rows as u64);
        let base = rng.normal_vec_f32(act.elems(), 1.0);
        let lora = rng.normal_vec_f32(act.elems(), 0.3);
        let g: Vec<f32> = (0..act.d_out)
            .map(|_| 1.0 + rng.normal() as f32 * 0.002)
            .collect();
        let s = 2.0f32;

        let mut temps = compose_cpu::EagerTemps::new(act);
        let mut out = vec![0f32; act.elems()];
        let eager = timing::bench("eager", cfg, || {
            compose_cpu::compose_eager_into(&base, &lora, &g, s, act, &mut temps, &mut out);
            std::hint::black_box(&out);
        });
        let fused = timing::bench("fused", cfg, || {
            compose_cpu::compose_fused_into(&base, &lora, &g, s, act, &mut out);
            std::hint::black_box(&out);
        });
        let mut inner = vec![0f32; act.elems()];
        let dual = timing::bench("dual", cfg, || {
            compose_cpu::compose_fused_dual_into(&base, &lora, &g, s, act, &mut out, &mut inner);
            std::hint::black_box((&out, &inner));
        });
        let speedup = eager.median_s / fused.median_s;
        speedups.push(speedup);
        // Useful traffic of the fused pass: 3 reads + 1 write, f32.
        let bytes = (4 * act.elems() * 4) as u64;
        t.row(vec![
            format!("{}x{}", act.rows, act.d_out),
            format!("{:.0}", (act.elems() * 4) as f64 / (1 << 20) as f64),
            fmt_secs(eager.median_s),
            fmt_secs(fused.median_s),
            fmt_secs(dual.median_s),
            fmt_speedup(speedup),
            format!("{:.1}", fused.throughput_gbps(bytes)),
        ]);
        json_shapes.push(Json::obj(vec![
            ("rows", Json::Num(act.rows as f64)),
            ("d_out", Json::Num(act.d_out as f64)),
            ("eager_s", Json::Num(eager.median_s)),
            ("fused_s", Json::Num(fused.median_s)),
            ("dual_s", Json::Num(dual.median_s)),
            ("speedup", Json::Num(speedup)),
        ]));

        // --- kernel-backend layer: thread scaling of ParallelTiledCpu ----
        let llc_exceeding =
            dorafactors::kernels::compose_working_set_bytes(act) > dorafactors::kernels::LLC_BYTES;
        let mut row = vec![format!("{}x{}", act.rows, act.d_out)];
        let mut best = f64::INFINITY;
        for threads in THREAD_SWEEP {
            let backend = ParallelTiledCpu::new(threads);
            let m = timing::bench("tiled", cfg, || {
                backend.forward(&base, &lora, &g, s, act, Dtype::F32, &mut out);
                std::hint::black_box(&out);
            });
            let vs_fused = fused.median_s / m.median_s;
            best = best.min(m.median_s);
            row.push(fmt_speedup(vs_fused));
            json_scaling.push(Json::obj(vec![
                ("rows", Json::Num(act.rows as f64)),
                ("d_out", Json::Num(act.d_out as f64)),
                ("threads", Json::Num(threads as f64)),
                ("median_s", Json::Num(m.median_s)),
                ("speedup_vs_fused", Json::Num(vs_fused)),
                ("llc_exceeding", Json::Bool(llc_exceeding)),
            ]));
            if threads == 4 && llc_exceeding && act.rows >= 4096 && act.d_out >= 4096 {
                llc_exceeding_t4.push(vs_fused);
            }
        }
        row.push(format!("{:.1}", bytes as f64 / best / 1e9));
        scaling.row(row);
    }

    println!("{}", t.to_markdown());
    println!(
        "geomean speedup: {} (paper compose-fwd geomeans: 1.47-2.70x across GPUs)",
        fmt_speedup(stats::geomean(&speedups))
    );
    println!("{}", scaling.to_markdown());

    // Emit the machine-readable trajectory.
    let json = Json::obj(vec![
        ("bench", Json::Str("compose_kernel".into())),
        ("dtype", Json::Str("f32".into())),
        ("cores", Json::Num(available_cores() as f64)),
        ("shapes", Json::Arr(json_shapes)),
        ("thread_scaling", Json::Arr(json_scaling)),
    ]);
    let path = std::env::var("DORA_BENCH_JSON")
        .unwrap_or_else(|_| "bench_results/compose_kernel.json".to_string());
    if let Some(dir) = std::path::Path::new(&path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("bench JSON written to {path}"),
        Err(e) => eprintln!("could not write bench JSON to {path}: {e}"),
    }

    assert!(
        stats::geomean(&speedups) > 1.2,
        "fused compose should beat the 4-pass chain on CPU"
    );
    // The mechanism check: fused wins at every shape.
    assert!(
        speedups.iter().all(|&s| s > 1.1),
        "fused lost somewhere: {speedups:?}"
    );
    // Parallel-backend acceptance: >= 1.5x over fused at 4 threads on
    // LLC-exceeding shapes — only meaningful with enough physical cores.
    if available_cores() >= 4 && !llc_exceeding_t4.is_empty() {
        let geo = stats::geomean(&llc_exceeding_t4);
        assert!(
            geo >= 1.5,
            "parallel-tiled @4 threads only {geo:.2}x over fused on LLC-exceeding shapes"
        );
        println!(
            "parallel-tiled @4 threads: {} geomean over fused (target >= 1.50x)",
            fmt_speedup(geo)
        );
    } else {
        println!(
            "(skipping parallel-backend speedup assertion: {} cores available)",
            available_cores()
        );
    }
}

fn available_cores() -> usize {
    dorafactors::dispatch::default_threads()
}
