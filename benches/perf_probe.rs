//! Perf-pass probe: candidate optimizations for the compose hot paths.
//! Used during the §Perf iteration (EXPERIMENTS.md); kept as a bench so
//! the measurements are reproducible.

use dorafactors::bench::timing::{bench, BenchCfg};
use dorafactors::dora::compose_cpu;
use dorafactors::dora::config::ActShape;
use dorafactors::util::rng::Rng;

/// Candidate A: current collect-based fused kernel (baseline).
/// Candidate B: into-buffer (no allocation) — the coordinator's reuse path.
/// Candidate C: into-buffer with precomputed (g-1) vector.
fn compose_fused_pregm1(
    base: &[f32],
    lora: &[f32],
    g: &[f32],
    gm1: &[f32],
    s: f32,
    act: ActShape,
    out: &mut [f32],
) {
    let d = act.d_out;
    for ((orow, brow), lrow) in out
        .chunks_exact_mut(d)
        .zip(base.chunks_exact(d))
        .zip(lora.chunks_exact(d))
    {
        for j in 0..d {
            orow[j] = gm1[j] * brow[j] + g[j] * (s * lrow[j]);
        }
    }
}

fn main() {
    let cfg = BenchCfg { warmup: 3, trials: 30, time_cap_s: 12.0 };
    for act in [ActShape::new(1024, 4096), ActShape::new(4096, 8192)] {
        let mut rng = Rng::new(1);
        let base = rng.normal_vec_f32(act.elems(), 1.0);
        let lora = rng.normal_vec_f32(act.elems(), 0.3);
        let g: Vec<f32> = (0..act.d_out).map(|_| 1.0 + rng.normal() as f32 * 0.002).collect();
        let gm1: Vec<f32> = g.iter().map(|&x| x - 1.0).collect();
        let mut out = vec![0f32; act.elems()];
        let bytes = (4 * act.elems() * 4) as u64;

        let a = bench("collect", cfg, || {
            std::hint::black_box(compose_cpu::compose_fused(&base, &lora, &g, 2.0, act));
        });
        let b = bench("into", cfg, || {
            compose_cpu::compose_fused_into(&base, &lora, &g, 2.0, act, &mut out);
            std::hint::black_box(&out);
        });
        let c = bench("into+pregm1", cfg, || {
            compose_fused_pregm1(&base, &lora, &g, &gm1, 2.0, act, &mut out);
            std::hint::black_box(&out);
        });
        println!(
            "{}x{}: collect {:.2} ms ({:.1} GB/s) | into {:.2} ms ({:.1} GB/s) | pregm1 {:.2} ms ({:.1} GB/s)",
            act.rows, act.d_out,
            a.median_s * 1e3, a.throughput_gbps(bytes),
            b.median_s * 1e3, b.throughput_gbps(bytes),
            c.median_s * 1e3, c.throughput_gbps(bytes),
        );
    }
}
