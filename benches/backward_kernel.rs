//! REAL-measurement bench: fused vs eager compose backward on CPU
//! (Figure 8 / Table 9 backward column's mechanism), plus the d_mag
//! deterministic reduction — driven through the kernel-backend layer's
//! `ComposeKernel` trait, with the KernelAgent two-stage fused-dmag path
//! (`backward_with_dmag`) and the parallel-tiled backend alongside.

use dorafactors::bench::{shapes, timing};
use dorafactors::kernels::{ComposeKernel, EagerCpu, FusedCpu, ParallelTiledCpu};
use dorafactors::numerics::Dtype;
use dorafactors::util::rng::Rng;
use dorafactors::util::stats;
use dorafactors::util::table::{fmt_secs, fmt_speedup, Table};

fn main() {
    let cfg = timing::BenchCfg { warmup: 3, trials: 30, time_cap_s: 15.0 };
    let mut t = Table::new(
        "compose backward (REAL CPU): eager 2-kernel vs fused dual-output \
vs KernelAgent two-stage (fused dmag) vs parallel-tiled",
        &[
            "rows x d_out",
            "eager+dmag",
            "fused+dmag",
            "KA fused-dmag",
            "par-tiled",
            "speedup",
            "KA speedup",
        ],
    );
    let mut speedups = Vec::new();
    let dt = Dtype::F32;
    for act in shapes::cpu_act_shapes() {
        let mut rng = Rng::new(act.d_out as u64);
        let d_delta = rng.normal_vec_f32(act.elems(), 1.0);
        let inner = rng.normal_vec_f32(act.elems(), 1.0);
        let g: Vec<f32> = (0..act.d_out)
            .map(|_| 1.0 + rng.normal() as f32 * 0.002)
            .collect();

        let mut dl = vec![0f32; act.elems()];
        let mut db = vec![0f32; act.elems()];

        // Full backward = pair kernel + the separate d_mag reduction
        // (the paper's shipped design), vs KernelAgent's fully fused
        // two-stage variant (§7), all through the backend trait.
        let eager = timing::bench("eager", cfg, || {
            EagerCpu.backward(&d_delta, &g, 2.0, act, dt, &mut dl, &mut db);
            std::hint::black_box(EagerCpu.dmag(&d_delta, &inner, act));
        });
        let fused = timing::bench("fused", cfg, || {
            FusedCpu.backward(&d_delta, &g, 2.0, act, dt, &mut dl, &mut db);
            std::hint::black_box(FusedCpu.dmag(&d_delta, &inner, act));
        });
        let ka = timing::bench("ka", cfg, || {
            std::hint::black_box(FusedCpu.backward_with_dmag(
                &d_delta, &inner, &g, 2.0, act, dt, &mut dl, &mut db,
            ));
        });
        let tiled_backend = ParallelTiledCpu::new(4);
        let tiled = timing::bench("tiled", cfg, || {
            std::hint::black_box(tiled_backend.backward_with_dmag(
                &d_delta, &inner, &g, 2.0, act, dt, &mut dl, &mut db,
            ));
        });
        let speedup = eager.median_s / fused.median_s;
        speedups.push(speedup);
        t.row(vec![
            format!("{}x{}", act.rows, act.d_out),
            fmt_secs(eager.median_s),
            fmt_secs(fused.median_s),
            fmt_secs(ka.median_s),
            fmt_secs(tiled.median_s),
            fmt_speedup(speedup),
            fmt_speedup(eager.median_s / ka.median_s),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "geomean backward speedup: {} (paper: 1.06-1.23x)",
        fmt_speedup(stats::geomean(&speedups))
    );
    // The fused backward reads d_delta once instead of twice, but pays
    // the dual-output store penalty (the paper's ROWS_PER_PROGRAM
    // pressure, FUSED_BWD_EFF in the cost model): on a single CPU core
    // interleaved two-stream stores can cancel the read saving, so wins
    // are modest-to-neutral — matching the paper's 1.06-1.23x band being
    // the SMALLEST of its speedups, with sub-crossover losses.
    assert!(stats::geomean(&speedups) > 0.55, "geomean {}", stats::geomean(&speedups));
}
