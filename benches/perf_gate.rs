//! CI perf gate: quick-mode compose/backward/pool measurements with a
//! machine-readable `BENCH_ci.json` summary and hard assertions on the
//! standing invariants:
//!
//! * fused compose <= eager compose (per quick shape, and geomean with
//!   headroom) — the paper's single-pass-vs-4-pass claim;
//! * merged fast path < composed at pool size 1 — the serving fast
//!   path's reason to exist;
//! * blocked GEMM >= 2x geomean over the branch-free naive loops on the
//!   e2e contraction shapes (2x per nt dot-chain row), and the small-K
//!   path beats the generic blocked core wherever dispatch picks it
//!   (r <= SMALL_K_MAX) — the PR6 micro-kernel claim, plus the
//!   zero-skip before/after trajectory rows;
//! * each adapter variant's fused forward (rsLoRA, BoRA) stays within
//!   1.2x of the Dora fused forward — the variant axis must not tax the
//!   compose hot path;
//! * streaming decode tokens/sec: merged > composed at pool {1, 2} —
//!   per-token, the precomputed merged weights must beat re-composing
//!   the adapter every step;
//! * multi-tenant merged-weight cache: {10, 100, 1000} adapters under
//!   bursty Zipf(1.0) vs uniform traffic at tight/loose byte budgets —
//!   the budget is never overshot, the 1000-adapter tight-budget Zipf
//!   row stays > 80% merged-hit with < 10% of merges resident, and Zipf
//!   beats uniform on hit rate at every size;
//! * precision (DESIGN.md §3.11): a bf16 merged replica accounts
//!   0.45-0.55x the f32 bytes (the half-budget serving contract), and
//!   the bf16 merged serving row holds >= 0.9x the f32 throughput at
//!   pool 1 (soft-bf16 rounds at shape-fixed points, so the merged hot
//!   loop pays only the elementwise rounding passes).
//!
//! Trial counts are sized for a CI runner (~seconds, not minutes); the
//! full-resolution sweeps live in `compose_kernel`, `backward_kernel`
//! and `coordinator_hotpath`. The JSON is written BEFORE the assertions
//! run, so a violated invariant still uploads the numbers that show it.
//!
//! Output: `bench_results/BENCH_ci.json` (override with
//! `$DORA_BENCH_JSON`). One `kernels` row per measured kernel with
//! ns/elem, plus `serving` rows per {pool, fast path}, `decode` rows
//! per {pool, fast path}, and `cache` rows per {adapters, mix, budget}.

use std::time::Duration;

use dorafactors::bench::timing;
use dorafactors::coordinator::{FastPath, GenOptions, Server, ServerCfg};
use dorafactors::dora::compose_cpu;
use dorafactors::dora::config::ActShape;
use dorafactors::kernels::gemm::{self, naive, SMALL_K_MAX};
use dorafactors::kernels::{ComposeKernel, EagerCpu, FusedCpu};
use dorafactors::models::forward::{self, NativeModel};
use dorafactors::numerics::Dtype;
use dorafactors::runtime::ops::{AdapterParams, AdapterVariant, Variant};
use dorafactors::runtime::{
    accounted_bytes, Adapter, BackendSpec, ConfigInfo, ExecBackend, InitReq, Precision, TensorData,
};
use dorafactors::util::json::Json;
use dorafactors::util::rng::Rng;
use dorafactors::util::stats;

fn main() {
    let cfg = timing::BenchCfg { warmup: 1, trials: 10, time_cap_s: 3.0 };
    let dt = Dtype::F32;
    // Quick sweep: one launch-bound, one mid, one LLC-exceeding shape.
    let quick_shapes = [
        ActShape::new(512, 2048),
        ActShape::new(1024, 4096),
        ActShape::new(4096, 4096),
    ];

    let mut kernel_rows: Vec<Json> = Vec::new();
    let mut compose_speedups = Vec::new();
    let mut compose_ok = true;

    for act in quick_shapes {
        let mut rng = Rng::new(act.rows as u64);
        let base = rng.normal_vec_f32(act.elems(), 1.0);
        let lora = rng.normal_vec_f32(act.elems(), 0.3);
        let g: Vec<f32> = (0..act.d_out)
            .map(|_| 1.0 + rng.normal() as f32 * 0.002)
            .collect();
        let s = 2.0f32;
        let mut out = vec![0f32; act.elems()];

        // Compose forward: eager 4-pass (caching-allocator regime) vs
        // fused single pass.
        let mut temps = compose_cpu::EagerTemps::new(act);
        let eager = timing::bench("compose eager", cfg, || {
            compose_cpu::compose_eager_into(&base, &lora, &g, s, act, &mut temps, &mut out);
            std::hint::black_box(&out);
        });
        let fused = timing::bench("compose fused", cfg, || {
            compose_cpu::compose_fused_into(&base, &lora, &g, s, act, &mut out);
            std::hint::black_box(&out);
        });
        let speedup = eager.median_s / fused.median_s;
        compose_speedups.push(speedup);
        compose_ok &= speedup >= 1.0;
        let ns_per_elem = |median_s: f64| median_s / act.elems() as f64 * 1e9;
        for (kernel, m) in [("compose_eager", &eager), ("compose_fused", &fused)] {
            kernel_rows.push(Json::obj(vec![
                ("kernel", Json::Str(kernel.into())),
                ("rows", Json::Num(act.rows as f64)),
                ("d_out", Json::Num(act.d_out as f64)),
                ("median_s", Json::Num(m.median_s)),
                ("ns_per_elem", Json::Num(ns_per_elem(m.median_s))),
            ]));
        }
        println!(
            "compose {}x{}: eager {:.2} ns/elem, fused {:.2} ns/elem ({:.2}x)",
            act.rows,
            act.d_out,
            ns_per_elem(eager.median_s),
            ns_per_elem(fused.median_s),
            speedup
        );

        // Backward: eager pair + separate dmag vs the KernelAgent fused
        // two-stage path. Reported for the trajectory; the paper's own
        // band (1.06-1.23x) admits sub-crossover losses on one CPU core,
        // so no hard gate here.
        let d_delta = rng.normal_vec_f32(act.elems(), 1.0);
        let inner = rng.normal_vec_f32(act.elems(), 1.0);
        let mut dl = vec![0f32; act.elems()];
        let mut db = vec![0f32; act.elems()];
        let bwd_eager = timing::bench("backward eager", cfg, || {
            EagerCpu.backward(&d_delta, &g, s, act, dt, &mut dl, &mut db);
            std::hint::black_box(EagerCpu.dmag(&d_delta, &inner, act));
        });
        let bwd_fused = timing::bench("backward fused", cfg, || {
            std::hint::black_box(FusedCpu.backward_with_dmag(
                &d_delta, &inner, &g, s, act, dt, &mut dl, &mut db,
            ));
        });
        let bwd_rows = [
            ("backward_eager_dmag", &bwd_eager),
            ("backward_fused_dmag", &bwd_fused),
        ];
        for (kernel, m) in bwd_rows {
            kernel_rows.push(Json::obj(vec![
                ("kernel", Json::Str(kernel.into())),
                ("rows", Json::Num(act.rows as f64)),
                ("d_out", Json::Num(act.d_out as f64)),
                ("median_s", Json::Num(m.median_s)),
                ("ns_per_elem", Json::Num(ns_per_elem(m.median_s))),
            ]));
        }
        println!(
            "backward {}x{}: eager+dmag {:.2} ns/elem, fused-dmag {:.2} ns/elem",
            act.rows,
            act.d_out,
            ns_per_elem(bwd_eager.median_s),
            ns_per_elem(bwd_fused.median_s)
        );
    }
    let compose_geomean = stats::geomean(&compose_speedups);

    // -----------------------------------------------------------------
    // Adapter-variant rows: the full fused forward per AdapterVariant on
    // a synthetic mid-size config. rsLoRA is the same kernel under the
    // rank-stabilized scale; BoRA adds the frozen factored column-norm
    // gain plus the input column scaling. Gate: either variant's compose
    // path stays within 1.2x of the Dora fused forward.
    // -----------------------------------------------------------------
    let mut variant_ratios: Vec<(&'static str, f64)> = Vec::new();
    {
        let (vd, vr, v_layers) = (128usize, 16usize, 2usize);
        let (v_seq, v_bs, v_vocab) = (32usize, 8usize, 256usize);
        let info = ConfigInfo {
            name: "bench".into(),
            vocab: v_vocab,
            d_model: vd,
            n_layers: v_layers,
            seq: v_seq,
            rank: vr,
            scale: 2.0,
            n_params: 0,
            train_batch: v_bs,
            chunk_steps: 1,
            frozen: forward::frozen_names(v_layers),
            trainable: forward::trainable_names(v_layers),
        };
        let leaves = forward::init_leaves(&info, 1234);
        let mut trainable = leaves.trainable;
        let mut vrng = Rng::new(55);
        for l in 0..v_layers {
            if let TensorData::F32(b) = &mut trainable[3 * l + 1].data {
                for x in b.iter_mut() {
                    *x = vrng.normal() as f32 * 0.1;
                }
            }
        }
        let params = AdapterParams { frozen: leaves.frozen, trainable };
        let tokens: Vec<i32> = (0..v_bs * v_seq).map(|i| (i * 7 % v_vocab) as i32).collect();
        let v_rows = v_bs * v_seq;
        let mut dora_s = f64::NAN;
        for adapter in AdapterVariant::ALL {
            let kernels = forward::kernels_for(Variant::Fused, &info, false).expect("kernels");
            let model = NativeModel::new(&info, &params.frozen, &params.trainable, kernels)
                .expect("bench model")
                .with_adapter(adapter);
            let m = timing::bench("variant forward", cfg, || {
                std::hint::black_box(model.infer_logits(&tokens, v_bs, v_seq).unwrap());
            });
            kernel_rows.push(Json::obj(vec![
                ("kernel", Json::Str("forward_fused".into())),
                ("variant", Json::Str(adapter.as_str().into())),
                ("rows", Json::Num(v_rows as f64)),
                ("d_out", Json::Num(vd as f64)),
                ("median_s", Json::Num(m.median_s)),
                ("ns_per_elem", Json::Num(m.median_s / (v_rows * vd) as f64 * 1e9)),
            ]));
            if adapter == AdapterVariant::Dora {
                dora_s = m.median_s;
            } else {
                variant_ratios.push((adapter.as_str(), m.median_s / dora_s));
            }
            println!(
                "forward fused {v_rows}x{vd} variant={}: {:.3} ms/fwd",
                adapter.as_str(),
                m.median_s * 1e3
            );
        }
    }
    let variant_ok = variant_ratios.iter().all(|(_, ratio)| *ratio <= 1.2);

    // -----------------------------------------------------------------
    // GEMM micro-kernel rows: the e2e-config contraction shapes
    // (d_model 128, rank 16, rows = bs*seq = 512, vocab 512), branch-free
    // naive loops vs the blocked/register-tiled cores in `kernels::gemm`,
    // normalized per MAC (m*k*n). `reps` lifts sub-ms shapes above timer
    // noise. Gate: blocked beats naive on every row, the dot-chain nt
    // rows by >= 2x, and >= 2x geomean across the set.
    // -----------------------------------------------------------------
    let (d, r, e2e_rows, vocab) = (128usize, 16usize, 512usize, 512usize);
    let mut grng = Rng::new(99);
    let h = grng.normal_vec_f32(e2e_rows * d, 0.1);
    let w = grng.normal_vec_f32(d * d, 0.1);
    let embed = grng.normal_vec_f32(vocab * d, 0.1);
    let dlogits = grng.normal_vec_f32(e2e_rows * vocab, 0.1);
    let du = grng.normal_vec_f32(e2e_rows * r, 0.1);

    let mut push_row = |kernel: String, m: usize, k: usize, n: usize, median_s: f64, ns: f64| {
        kernel_rows.push(Json::obj(vec![
            ("kernel", Json::Str(kernel)),
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
            ("median_s", Json::Num(median_s)),
            ("ns_per_mac", Json::Num(ns)),
        ]));
    };

    struct GemmCase<'a> {
        name: &'static str,
        m: usize,
        k: usize,
        n: usize,
        naive: Box<dyn Fn() -> Vec<f32> + 'a>,
        blocked: Box<dyn Fn() -> Vec<f32> + 'a>,
        /// Scalar-dot-chain baseline (nt): held to the full 2x bar alone.
        is_nt: bool,
    }
    let gemm_cases: Vec<GemmCase> = vec![
        GemmCase {
            name: "gemm_e2e_fwd_base_nt",
            m: e2e_rows,
            k: d,
            n: d,
            naive: Box::new(|| naive::nt(&h, &w, e2e_rows, d, d)),
            blocked: Box::new(|| gemm::nt(&h, &w, e2e_rows, d, d)),
            is_nt: true,
        },
        GemmCase {
            name: "gemm_e2e_fwd_logits_nt",
            m: e2e_rows,
            k: d,
            n: vocab,
            naive: Box::new(|| naive::nt(&h, &embed, e2e_rows, d, vocab)),
            blocked: Box::new(|| gemm::nt(&h, &embed, e2e_rows, d, vocab)),
            is_nt: true,
        },
        GemmCase {
            name: "gemm_e2e_bwd_dh_nn",
            m: e2e_rows,
            k: vocab,
            n: d,
            naive: Box::new(|| naive::nn(&dlogits, &embed, e2e_rows, vocab, d)),
            blocked: Box::new(|| gemm::nn(&dlogits, &embed, e2e_rows, vocab, d)),
            is_nt: false,
        },
        GemmCase {
            name: "gemm_e2e_bwd_da_tn",
            m: r,
            k: e2e_rows,
            n: d,
            naive: Box::new(|| naive::tn(&du, &h, e2e_rows, r, d)),
            blocked: Box::new(|| gemm::tn(&du, &h, e2e_rows, r, d)),
            is_nt: false,
        },
    ];
    let mut gemm_speedups = Vec::new();
    let (mut gemm_ok, mut gemm_nt_ok) = (true, true);
    for case in &gemm_cases {
        let macs = case.m * case.k * case.n;
        let reps = (4_000_000 / macs.max(1)).max(1);
        let nv = timing::bench(case.name, cfg, || {
            for _ in 0..reps {
                std::hint::black_box((case.naive)());
            }
        });
        let bv = timing::bench(case.name, cfg, || {
            for _ in 0..reps {
                std::hint::black_box((case.blocked)());
            }
        });
        let per_mac = |s: f64| s / (macs * reps) as f64 * 1e9;
        push_row(format!("{}_naive", case.name), case.m, case.k, case.n, nv.median_s, per_mac(nv.median_s));
        push_row(format!("{}_blocked", case.name), case.m, case.k, case.n, bv.median_s, per_mac(bv.median_s));
        let sp = nv.median_s / bv.median_s;
        gemm_speedups.push(sp);
        gemm_ok &= sp >= 0.9; // every row at least holds ground (0.9 absorbs runner noise)
        if case.is_nt {
            gemm_nt_ok &= sp >= 2.0;
        }
        println!(
            "{} {}x{}x{}: naive {:.3} ns/MAC, blocked {:.3} ns/MAC ({sp:.2}x)",
            case.name,
            case.m,
            case.k,
            case.n,
            per_mac(nv.median_s),
            per_mac(bv.median_s)
        );
    }
    let gemm_geomean = stats::geomean(&gemm_speedups);

    // Zero-skip before/after (the old `matmul_tn` data-dependent branch,
    // removed in PR6): same bwd_da shape, branchy vs branch-free naive.
    // Reported as trajectory rows, no gate — on zero-free data the win is
    // the branch overhead itself, not a throughput cliff.
    {
        let macs = r * e2e_rows * d;
        let reps = (4_000_000 / macs.max(1)).max(1);
        let branchy = timing::bench("tn zero-skip", cfg, || {
            for _ in 0..reps {
                std::hint::black_box(branchy_tn(&du, &h, e2e_rows, r, d));
            }
        });
        let clean = timing::bench("tn branch-free", cfg, || {
            for _ in 0..reps {
                std::hint::black_box(naive::tn(&du, &h, e2e_rows, r, d));
            }
        });
        let per_mac = |s: f64| s / (macs * reps) as f64 * 1e9;
        push_row("gemm_tn_zeroskip_before".into(), r, e2e_rows, d, branchy.median_s, per_mac(branchy.median_s));
        push_row("gemm_tn_zeroskip_after".into(), r, e2e_rows, d, clean.median_s, per_mac(clean.median_s));
        println!(
            "tn zero-skip removal: before {:.3} ns/MAC, after {:.3} ns/MAC ({:.2}x)",
            per_mac(branchy.median_s),
            per_mac(clean.median_s),
            branchy.median_s / clean.median_s
        );
    }

    // Small-K dispatch sweep: the adapter B@A shape at e2e d_model
    // (m = n = 128) across ranks {8, 64, 384} — naive vs the forced
    // generic blocked core vs the small-K path. Gate: small-K beats
    // generic wherever dispatch actually picks it (r <= SMALL_K_MAX).
    let mut smallk_ok = true;
    for rank in [8usize, 64, 384] {
        let bw = grng.normal_vec_f32(d * rank, 0.1);
        let aw = grng.normal_vec_f32(rank * d, 0.1);
        let macs = d * rank * d;
        let reps = (4_000_000 / macs.max(1)).max(1);
        let nv = timing::bench("ba naive", cfg, || {
            for _ in 0..reps {
                std::hint::black_box(naive::nn(&bw, &aw, d, rank, d));
            }
        });
        let gv = timing::bench("ba blocked", cfg, || {
            for _ in 0..reps {
                std::hint::black_box(gemm::nn_blocked(&bw, &aw, d, rank, d));
            }
        });
        let sv = timing::bench("ba small-k", cfg, || {
            for _ in 0..reps {
                std::hint::black_box(gemm::nn_small_k(&bw, &aw, d, rank, d));
            }
        });
        let per_mac = |s: f64| s / (macs * reps) as f64 * 1e9;
        push_row(format!("gemm_ba_r{rank}_naive"), d, rank, d, nv.median_s, per_mac(nv.median_s));
        push_row(format!("gemm_ba_r{rank}_blocked"), d, rank, d, gv.median_s, per_mac(gv.median_s));
        push_row(format!("gemm_ba_r{rank}_smallk"), d, rank, d, sv.median_s, per_mac(sv.median_s));
        if rank <= SMALL_K_MAX {
            smallk_ok &= sv.median_s < gv.median_s;
        }
        println!(
            "gemm B@A 128x{rank}x128: naive {:.3}, blocked {:.3}, small-K {:.3} ns/MAC",
            per_mac(nv.median_s),
            per_mac(gv.median_s),
            per_mac(sv.median_s)
        );
    }

    // Serving pool: per-request round-trip at pool {1, 2} x {merged,
    // composed} on the `small` config (no batching window, so the rows
    // isolate per-request latency).
    let mut serving_rows: Vec<Json> = Vec::new();
    let mut medians: Vec<((usize, &'static str), f64)> = Vec::new();
    for pool in [1usize, 2] {
        for fast_path in [FastPath::Merged, FastPath::Composed] {
            let server = Server::start(
                BackendSpec::Native,
                ServerCfg {
                    config: "small".into(),
                    max_wait: Duration::ZERO,
                    workers: pool,
                    fast_path,
                    queue_depth: 32,
                    ..ServerCfg::default()
                },
            )
            .expect("pool server");
            let client = server.client();
            let serve_cfg = timing::BenchCfg { warmup: 2, trials: 20, time_cap_s: 3.0 };
            let m = timing::bench("pool rtt", serve_cfg, || {
                client.infer(&[1, 2, 3, 4]).unwrap();
            });
            drop(client);
            let sm = server.shutdown();
            assert_eq!(sm.fast_path, fast_path.as_str(), "requested path not effective");
            medians.push(((pool, fast_path.as_str()), m.median_s));
            serving_rows.push(Json::obj(vec![
                ("pool", Json::Num(pool as f64)),
                ("fast_path", Json::Str(fast_path.as_str().into())),
                ("median_s", Json::Num(m.median_s)),
                ("req_per_s", Json::Num(1.0 / m.median_s)),
            ]));
            println!(
                "serve small pool={pool} path={}: {:.0} us/req",
                fast_path.as_str(),
                m.median_s * 1e6
            );
        }
    }
    let median_of = |pool: usize, path: &str| -> f64 {
        medians
            .iter()
            .find(|((p, fp), _)| *p == pool && *fp == path)
            .map(|(_, v)| *v)
            .expect("median recorded")
    };
    let (merged1, composed1) = (median_of(1, "merged"), median_of(1, "composed"));
    let merged_ok = merged1 < composed1;

    // Streaming decode: tokens/sec through the continuous-batching
    // scheduler at pool {1, 2} x {merged, composed} on the `small`
    // config (one greedy 32-token stream per trial — the steady-state
    // per-token path, not the batching window). Gate: the merged fast
    // path out-decodes composed at BOTH pool sizes — per-token the
    // one-matmul-per-layer merged step is the whole point.
    let mut decode_rows: Vec<Json> = Vec::new();
    let mut decode_medians: Vec<((usize, &'static str), f64)> = Vec::new();
    const DECODE_TOKENS: usize = 32;
    for pool in [1usize, 2] {
        for fast_path in [FastPath::Merged, FastPath::Composed] {
            let server = Server::start(
                BackendSpec::Native,
                ServerCfg {
                    config: "small".into(),
                    max_wait: Duration::ZERO,
                    workers: pool,
                    fast_path,
                    queue_depth: 32,
                    ..ServerCfg::default()
                },
            )
            .expect("decode server");
            let client = server.client();
            let opts = GenOptions { max_tokens: DECODE_TOKENS, ..GenOptions::default() };
            let serve_cfg = timing::BenchCfg { warmup: 1, trials: 10, time_cap_s: 3.0 };
            let m = timing::bench("decode stream", serve_cfg, || {
                let tokens = client.generate_collect(&[1, 2, 3, 4], opts).unwrap();
                assert_eq!(tokens.len(), DECODE_TOKENS);
            });
            drop(client);
            let sm = server.shutdown();
            assert_eq!(sm.decode_failed, 0, "decode bench stream failed");
            let tok_per_s = DECODE_TOKENS as f64 / m.median_s;
            decode_medians.push(((pool, fast_path.as_str()), m.median_s));
            decode_rows.push(Json::obj(vec![
                ("pool", Json::Num(pool as f64)),
                ("fast_path", Json::Str(fast_path.as_str().into())),
                ("tokens", Json::Num(DECODE_TOKENS as f64)),
                ("median_s", Json::Num(m.median_s)),
                ("tok_per_s", Json::Num(tok_per_s)),
            ]));
            println!(
                "decode small pool={pool} path={}: {:.0} tok/s ({:.1} us/token)",
                fast_path.as_str(),
                tok_per_s,
                m.median_s / DECODE_TOKENS as f64 * 1e6
            );
        }
    }
    let decode_of = |pool: usize, path: &str| -> f64 {
        decode_medians
            .iter()
            .find(|((p, fp), _)| *p == pool && *fp == path)
            .map(|(_, v)| *v)
            .expect("decode median recorded")
    };
    let decode_ok = decode_of(1, "merged") < decode_of(1, "composed")
        && decode_of(2, "merged") < decode_of(2, "composed");

    // -----------------------------------------------------------------
    // Multi-tenant merged-weight cache: {10, 100, 1000} synthetic tiny
    // adapters served under a byte budget, bursty Zipf(1.0) traffic vs
    // uniform. "tight" holds < 10% of the merges (never fewer than 2),
    // "loose" holds every merge. Traffic arrives in bursts of 6
    // sequential requests per sampled adapter — the realistic arrival
    // shape that gives the async builder a promotion window. Gates: the
    // resident high-water mark never exceeds the budget in any scenario;
    // the 1000-adapter tight-budget Zipf row ends with > 80% steady-state
    // merged hit rate while holding < 100 merges; per size, Zipf beats
    // uniform on hit rate, and on throughput the geomean Zipf/uniform
    // ratio stays above a 0.9 noise floor (the wall-clock side of the
    // ordering; the counter side is gated strictly).
    // -----------------------------------------------------------------
    const TINY_MERGE_BYTES: u64 = 16 * 1024; // accounted bytes of one tiny merge
    const BURST: usize = 6;
    const WARM_BURSTS: usize = 50;
    const MEASURE_BURSTS: usize = 150;
    let mut cache_rows: Vec<Json> = Vec::new();
    // (adapters, mix, budget label, hit rate, req/s, resident at end)
    let mut cache_results: Vec<(usize, &'static str, &'static str, f64, f64, usize)> = Vec::new();
    let mut cache_budget_ok = true;
    let be = ExecBackend::native();
    let tiny_info = be.config("tiny").expect("tiny config");
    for n_adapters in [10usize, 100, 1000] {
        let adapters: Vec<Adapter> = (0..n_adapters)
            .map(|i| {
                let init = be
                    .init(InitReq {
                        config: "tiny".into(),
                        seed: i as i32,
                        precision: Precision::F32,
                    })
                    .expect("init");
                Adapter::new(format!("a{i}"), &tiny_info, i as u64, 0, init.params)
                    .expect("adapter")
            })
            .collect();
        // Zipf(1.0) CDF over adapter ranks (adapter i has weight 1/(i+1)).
        let weights: Vec<f64> = (0..n_adapters).map(|i| 1.0 / (i + 1) as f64).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf: Vec<f64> = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        let tight = (n_adapters as u64 / 12).max(2) * TINY_MERGE_BYTES;
        let loose = n_adapters as u64 * TINY_MERGE_BYTES;
        for (mix, budget_label, budget) in
            [("zipf", "tight", tight), ("zipf", "loose", loose), ("uniform", "tight", tight)]
        {
            let server = Server::start_with_adapters(
                BackendSpec::Native,
                ServerCfg {
                    config: "tiny".into(),
                    max_wait: Duration::ZERO,
                    workers: 1,
                    fast_path: FastPath::Merged,
                    queue_depth: 32,
                    merge_budget: Some(budget),
                    ..ServerCfg::default()
                },
                adapters.clone(),
            )
            .expect("cache server");
            let client = server.client();
            let mut rng = Rng::new(4242 + n_adapters as u64);
            let sample = |rng: &mut Rng| -> usize {
                if mix == "uniform" {
                    rng.below(n_adapters as u64) as usize
                } else {
                    let u = rng.next_f64();
                    cdf.partition_point(|c| *c < u).min(n_adapters - 1)
                }
            };
            for _ in 0..WARM_BURSTS {
                let a = format!("a{}", sample(&mut rng));
                for t in 0..BURST {
                    client.infer_with(&a, &[t as i32 + 1, 2, 3]).expect("warm request");
                }
            }
            let m0 = server.metrics();
            let start = std::time::Instant::now();
            for _ in 0..MEASURE_BURSTS {
                let a = format!("a{}", sample(&mut rng));
                for t in 0..BURST {
                    client.infer_with(&a, &[t as i32 + 1, 2, 3]).expect("measured request");
                }
            }
            let elapsed = start.elapsed().as_secs_f64();
            let m1 = server.metrics();
            drop(client);
            let m_final = server.shutdown();
            let n_req = (MEASURE_BURSTS * BURST) as f64;
            let (dh, dm) =
                (m1.cache_hits - m0.cache_hits, m1.cache_misses - m0.cache_misses);
            let hit_rate = dh as f64 / (dh + dm).max(1) as f64;
            let req_per_s = n_req / elapsed;
            cache_budget_ok &= m_final.cache_high_water_bytes <= budget
                && m_final.merge_budget_bytes == budget;
            cache_results.push((
                n_adapters,
                mix,
                budget_label,
                hit_rate,
                req_per_s,
                m1.cache_resident,
            ));
            cache_rows.push(Json::obj(vec![
                ("adapters", Json::Num(n_adapters as f64)),
                ("mix", Json::Str(mix.into())),
                ("budget", Json::Str(budget_label.into())),
                ("budget_bytes", Json::Num(budget as f64)),
                ("median_s", Json::Num(elapsed / n_req)),
                ("req_per_s", Json::Num(req_per_s)),
                ("hit_rate", Json::Num(hit_rate)),
                ("evictions", Json::Num(m_final.cache_evictions as f64)),
                ("high_water_bytes", Json::Num(m_final.cache_high_water_bytes as f64)),
            ]));
            println!(
                "cache tiny adapters={n_adapters} mix={mix} budget={budget_label}: \
                 hit rate {hit_rate:.3}, {req_per_s:.0} req/s, resident {}, \
                 evictions {}, high water {} KiB of {} KiB",
                m1.cache_resident,
                m_final.cache_evictions,
                m_final.cache_high_water_bytes / 1024,
                budget / 1024
            );
        }
    }
    let cache_of = |n: usize, mix: &str, label: &str| -> (f64, f64, usize) {
        cache_results
            .iter()
            .find(|(cn, cm, cl, ..)| *cn == n && *cm == mix && *cl == label)
            .map(|&(_, _, _, hit, rps, res)| (hit, rps, res))
            .expect("cache scenario recorded")
    };
    let (zipf1000_hit, _, zipf1000_resident) = cache_of(1000, "zipf", "tight");
    let cache_zipf1000_ok = zipf1000_hit > 0.8 && zipf1000_resident < 100;
    let cache_hits_ordered = [10usize, 100, 1000]
        .iter()
        .all(|&n| cache_of(n, "zipf", "tight").0 > cache_of(n, "uniform", "tight").0);
    let cache_tput_ratio = stats::geomean(
        &[10usize, 100, 1000]
            .iter()
            .map(|&n| cache_of(n, "zipf", "tight").1 / cache_of(n, "uniform", "tight").1)
            .collect::<Vec<_>>(),
    );

    // -----------------------------------------------------------------
    // Precision rows (DESIGN.md §3.11): the bf16 serving operating
    // point. Byte side: a bf16 merged replica accounts ~half the f32
    // bytes — the cache-budget contract that lets a bf16 fleet fit ~2x
    // the adapters. Throughput side: the merged fast path served at
    // bf16 stays within 0.9x of the f32 row — soft-bf16 rounds at
    // shape-fixed points only, so the merged hot loop may pay at most
    // the elementwise rounding passes, never a kernel regression.
    // -----------------------------------------------------------------
    let (merged_bytes_f32, merged_bytes_bf16) = {
        let init = be
            .init(InitReq { config: "tiny".into(), seed: 7, precision: Precision::F32 })
            .expect("precision init");
        let bytes = |precision| {
            let merged = forward::merge_adapter_params(
                &tiny_info,
                &init.params,
                AdapterVariant::Dora,
                precision,
            )
            .expect("precision merge");
            accounted_bytes(&merged)
        };
        (bytes(Precision::F32), bytes(Precision::Bf16))
    };
    let bytes_ratio = merged_bytes_bf16 as f64 / merged_bytes_f32 as f64;
    let precision_bytes_ok = (0.45..=0.55).contains(&bytes_ratio);
    println!(
        "merged replica bytes tiny: f32 {merged_bytes_f32} B, bf16 {merged_bytes_bf16} B \
         ({bytes_ratio:.2}x)"
    );

    let bf16_merged1 = {
        let server = Server::start(
            BackendSpec::Native,
            ServerCfg {
                config: "small".into(),
                max_wait: Duration::ZERO,
                workers: 1,
                fast_path: FastPath::Merged,
                queue_depth: 32,
                precision: Precision::Bf16,
                ..ServerCfg::default()
            },
        )
        .expect("bf16 pool server");
        let client = server.client();
        let serve_cfg = timing::BenchCfg { warmup: 2, trials: 20, time_cap_s: 3.0 };
        let m = timing::bench("bf16 pool rtt", serve_cfg, || {
            client.infer(&[1, 2, 3, 4]).unwrap();
        });
        drop(client);
        server.shutdown();
        m.median_s
    };
    serving_rows.push(Json::obj(vec![
        ("pool", Json::Num(1.0)),
        ("fast_path", Json::Str("merged".into())),
        ("precision", Json::Str("bf16".into())),
        ("median_s", Json::Num(bf16_merged1)),
        ("req_per_s", Json::Num(1.0 / bf16_merged1)),
    ]));
    let bf16_tput_ratio = merged1 / bf16_merged1;
    let precision_tput_ok = bf16_tput_ratio >= 0.9;
    println!(
        "serve small pool=1 path=merged precision=bf16: {:.0} us/req ({bf16_tput_ratio:.2}x f32)",
        bf16_merged1 * 1e6
    );

    // Emit the summary BEFORE asserting: a violated invariant must still
    // upload the numbers that show it.
    let json = Json::obj(vec![
        ("bench", Json::Str("perf_gate".into())),
        ("kernels", Json::Arr(kernel_rows)),
        ("serving", Json::Arr(serving_rows)),
        ("decode", Json::Arr(decode_rows)),
        ("cache", Json::Arr(cache_rows)),
        ("compose_geomean_speedup", Json::Num(compose_geomean)),
        ("gemm_geomean_speedup", Json::Num(gemm_geomean)),
        (
            "precision",
            Json::obj(vec![
                ("merged_bytes_f32", Json::Num(merged_bytes_f32 as f64)),
                ("merged_bytes_bf16", Json::Num(merged_bytes_bf16 as f64)),
                ("bytes_ratio", Json::Num(bytes_ratio)),
                ("merged_pool1_tput_ratio", Json::Num(bf16_tput_ratio)),
            ]),
        ),
        (
            "invariants",
            Json::obj(vec![
                ("fused_le_eager", Json::Bool(compose_ok)),
                ("merged_lt_composed_pool1", Json::Bool(merged_ok)),
                ("decode_merged_gt_composed", Json::Bool(decode_ok)),
                ("gemm_blocked_beats_naive_e2e", Json::Bool(gemm_ok)),
                ("gemm_nt_2x_e2e", Json::Bool(gemm_nt_ok)),
                ("smallk_beats_blocked_r_le_64", Json::Bool(smallk_ok)),
                ("variant_forward_le_1p2x_dora", Json::Bool(variant_ok)),
                ("cache_budget_never_exceeded", Json::Bool(cache_budget_ok)),
                ("cache_zipf1000_hot", Json::Bool(cache_zipf1000_ok)),
                ("cache_zipf_hits_beat_uniform", Json::Bool(cache_hits_ordered)),
                ("bf16_merged_bytes_half_f32", Json::Bool(precision_bytes_ok)),
                ("bf16_merged_tput_ge_0p9_f32", Json::Bool(precision_tput_ok)),
            ]),
        ),
    ]);
    let path = std::env::var("DORA_BENCH_JSON")
        .unwrap_or_else(|_| "bench_results/BENCH_ci.json".to_string());
    if let Some(dir) = std::path::Path::new(&path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("perf-gate JSON written to {path}"),
        Err(e) => eprintln!("could not write perf-gate JSON to {path}: {e}"),
    }

    assert!(
        compose_ok,
        "fused compose lost to eager somewhere: speedups {compose_speedups:?}"
    );
    assert!(
        compose_geomean >= 1.1,
        "fused compose geomean speedup {compose_geomean:.2} < 1.1"
    );
    assert!(
        merged_ok,
        "merged fast path not faster at pool=1: merged {merged1:.3e}s vs composed {composed1:.3e}s"
    );
    assert!(
        gemm_ok,
        "blocked GEMM lost ground to naive on an e2e row: speedups {gemm_speedups:?}"
    );
    assert!(
        gemm_nt_ok,
        "blocked GEMM under 2x on a dot-chain nt row: speedups {gemm_speedups:?}"
    );
    assert!(
        gemm_geomean >= 2.0,
        "blocked GEMM geomean speedup {gemm_geomean:.2} < 2.0 on the e2e rows"
    );
    assert!(smallk_ok, "small-K path lost to generic blocked at r <= {SMALL_K_MAX}");
    assert!(
        decode_ok,
        "merged decode did not out-decode composed at some pool size: \
         pool1 merged {:.3e}s vs composed {:.3e}s, pool2 merged {:.3e}s vs composed {:.3e}s",
        decode_of(1, "merged"),
        decode_of(1, "composed"),
        decode_of(2, "merged"),
        decode_of(2, "composed")
    );
    assert!(
        variant_ok,
        "an adapter variant's fused forward exceeded 1.2x the Dora forward: {variant_ratios:?}"
    );
    assert!(
        cache_budget_ok,
        "merged-weight cache overshot its byte budget in some scenario: {cache_results:?}"
    );
    assert!(
        cache_zipf1000_ok,
        "1000-adapter tight-budget Zipf row not hot enough: hit rate {zipf1000_hit:.3} \
         (need > 0.8), resident {zipf1000_resident} (need < 100)"
    );
    assert!(
        cache_hits_ordered,
        "Zipf traffic did not beat uniform on merged hit rate at every size: {cache_results:?}"
    );
    assert!(
        cache_tput_ratio >= 0.9,
        "Zipf throughput fell more than the noise floor below uniform: \
         geomean ratio {cache_tput_ratio:.3} < 0.9 ({cache_results:?})"
    );
    assert!(
        precision_bytes_ok,
        "bf16 merged replica is not ~half the f32 bytes: \
         {merged_bytes_bf16} B vs {merged_bytes_f32} B ({bytes_ratio:.2}x, need 0.45-0.55)"
    );
    assert!(
        precision_tput_ok,
        "bf16 merged serving fell below 0.9x f32 throughput: \
         {bf16_merged1:.3e}s vs {merged1:.3e}s ({bf16_tput_ratio:.2}x)"
    );
    println!(
        "perf gate OK: compose geomean {compose_geomean:.2}x, gemm geomean {gemm_geomean:.2}x, \
         merged/composed {:.2}x, zipf/uniform cache throughput {cache_tput_ratio:.2}x",
        composed1 / merged1
    );
}

/// The pre-PR6 `matmul_tn` inner loop, zero-skip branch included — kept
/// only here so the gate can keep showing what removing the
/// data-dependent branch is worth on the same shape.
fn branchy_tn(a: &[f32], b: &[f32], rows: usize, n1: usize, n2: usize) -> Vec<f32> {
    let mut c = vec![0f32; n1 * n2];
    for i in 0..rows {
        let arow = &a[i * n1..(i + 1) * n1];
        let brow = &b[i * n2..(i + 1) * n2];
        for p in 0..n1 {
            let ap = arow[p];
            if ap == 0.0 {
                continue;
            }
            let crow = &mut c[p * n2..(p + 1) * n2];
            for q in 0..n2 {
                crow[q] += ap * brow[q];
            }
        }
    }
    c
}
