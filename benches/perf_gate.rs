//! CI perf gate: quick-mode compose/backward/pool measurements with a
//! machine-readable `BENCH_ci.json` summary and hard assertions on the
//! standing invariants:
//!
//! * fused compose <= eager compose (per quick shape, and geomean with
//!   headroom) — the paper's single-pass-vs-4-pass claim;
//! * merged fast path < composed at pool size 1 — the serving fast
//!   path's reason to exist.
//!
//! Trial counts are sized for a CI runner (~seconds, not minutes); the
//! full-resolution sweeps live in `compose_kernel`, `backward_kernel`
//! and `coordinator_hotpath`. The JSON is written BEFORE the assertions
//! run, so a violated invariant still uploads the numbers that show it.
//!
//! Output: `bench_results/BENCH_ci.json` (override with
//! `$DORA_BENCH_JSON`). One `kernels` row per measured kernel with
//! ns/elem, plus `serving` rows per {pool, fast path}.

use std::time::Duration;

use dorafactors::bench::timing;
use dorafactors::coordinator::{FastPath, Server, ServerCfg};
use dorafactors::dora::compose_cpu;
use dorafactors::dora::config::ActShape;
use dorafactors::kernels::{ComposeKernel, EagerCpu, FusedCpu};
use dorafactors::numerics::Dtype;
use dorafactors::runtime::BackendSpec;
use dorafactors::util::json::Json;
use dorafactors::util::rng::Rng;
use dorafactors::util::stats;

fn main() {
    let cfg = timing::BenchCfg { warmup: 1, trials: 10, time_cap_s: 3.0 };
    let dt = Dtype::F32;
    // Quick sweep: one launch-bound, one mid, one LLC-exceeding shape.
    let quick_shapes = [
        ActShape::new(512, 2048),
        ActShape::new(1024, 4096),
        ActShape::new(4096, 4096),
    ];

    let mut kernel_rows: Vec<Json> = Vec::new();
    let mut compose_speedups = Vec::new();
    let mut compose_ok = true;

    for act in quick_shapes {
        let mut rng = Rng::new(act.rows as u64);
        let base = rng.normal_vec_f32(act.elems(), 1.0);
        let lora = rng.normal_vec_f32(act.elems(), 0.3);
        let g: Vec<f32> = (0..act.d_out)
            .map(|_| 1.0 + rng.normal() as f32 * 0.002)
            .collect();
        let s = 2.0f32;
        let mut out = vec![0f32; act.elems()];

        // Compose forward: eager 4-pass (caching-allocator regime) vs
        // fused single pass.
        let mut temps = compose_cpu::EagerTemps::new(act);
        let eager = timing::bench("compose eager", cfg, || {
            compose_cpu::compose_eager_into(&base, &lora, &g, s, act, &mut temps, &mut out);
            std::hint::black_box(&out);
        });
        let fused = timing::bench("compose fused", cfg, || {
            compose_cpu::compose_fused_into(&base, &lora, &g, s, act, &mut out);
            std::hint::black_box(&out);
        });
        let speedup = eager.median_s / fused.median_s;
        compose_speedups.push(speedup);
        compose_ok &= speedup >= 1.0;
        let ns_per_elem = |median_s: f64| median_s / act.elems() as f64 * 1e9;
        for (kernel, m) in [("compose_eager", &eager), ("compose_fused", &fused)] {
            kernel_rows.push(Json::obj(vec![
                ("kernel", Json::Str(kernel.into())),
                ("rows", Json::Num(act.rows as f64)),
                ("d_out", Json::Num(act.d_out as f64)),
                ("median_s", Json::Num(m.median_s)),
                ("ns_per_elem", Json::Num(ns_per_elem(m.median_s))),
            ]));
        }
        println!(
            "compose {}x{}: eager {:.2} ns/elem, fused {:.2} ns/elem ({:.2}x)",
            act.rows,
            act.d_out,
            ns_per_elem(eager.median_s),
            ns_per_elem(fused.median_s),
            speedup
        );

        // Backward: eager pair + separate dmag vs the KernelAgent fused
        // two-stage path. Reported for the trajectory; the paper's own
        // band (1.06-1.23x) admits sub-crossover losses on one CPU core,
        // so no hard gate here.
        let d_delta = rng.normal_vec_f32(act.elems(), 1.0);
        let inner = rng.normal_vec_f32(act.elems(), 1.0);
        let mut dl = vec![0f32; act.elems()];
        let mut db = vec![0f32; act.elems()];
        let bwd_eager = timing::bench("backward eager", cfg, || {
            EagerCpu.backward(&d_delta, &g, s, act, dt, &mut dl, &mut db);
            std::hint::black_box(EagerCpu.dmag(&d_delta, &inner, act));
        });
        let bwd_fused = timing::bench("backward fused", cfg, || {
            std::hint::black_box(FusedCpu.backward_with_dmag(
                &d_delta, &inner, &g, s, act, dt, &mut dl, &mut db,
            ));
        });
        let bwd_rows = [
            ("backward_eager_dmag", &bwd_eager),
            ("backward_fused_dmag", &bwd_fused),
        ];
        for (kernel, m) in bwd_rows {
            kernel_rows.push(Json::obj(vec![
                ("kernel", Json::Str(kernel.into())),
                ("rows", Json::Num(act.rows as f64)),
                ("d_out", Json::Num(act.d_out as f64)),
                ("median_s", Json::Num(m.median_s)),
                ("ns_per_elem", Json::Num(ns_per_elem(m.median_s))),
            ]));
        }
        println!(
            "backward {}x{}: eager+dmag {:.2} ns/elem, fused-dmag {:.2} ns/elem",
            act.rows,
            act.d_out,
            ns_per_elem(bwd_eager.median_s),
            ns_per_elem(bwd_fused.median_s)
        );
    }
    let compose_geomean = stats::geomean(&compose_speedups);

    // Serving pool: per-request round-trip at pool {1, 2} x {merged,
    // composed} on the `small` config (no batching window, so the rows
    // isolate per-request latency).
    let mut serving_rows: Vec<Json> = Vec::new();
    let mut medians: Vec<((usize, &'static str), f64)> = Vec::new();
    for pool in [1usize, 2] {
        for fast_path in [FastPath::Merged, FastPath::Composed] {
            let server = Server::start(
                BackendSpec::Native,
                ServerCfg {
                    config: "small".into(),
                    max_wait: Duration::ZERO,
                    workers: pool,
                    fast_path,
                },
            )
            .expect("pool server");
            let client = server.client();
            let serve_cfg = timing::BenchCfg { warmup: 2, trials: 20, time_cap_s: 3.0 };
            let m = timing::bench("pool rtt", serve_cfg, || {
                client.infer(&[1, 2, 3, 4]).unwrap();
            });
            drop(client);
            let sm = server.shutdown();
            assert_eq!(sm.fast_path, fast_path.as_str(), "requested path not effective");
            medians.push(((pool, fast_path.as_str()), m.median_s));
            serving_rows.push(Json::obj(vec![
                ("pool", Json::Num(pool as f64)),
                ("fast_path", Json::Str(fast_path.as_str().into())),
                ("median_s", Json::Num(m.median_s)),
                ("req_per_s", Json::Num(1.0 / m.median_s)),
            ]));
            println!(
                "serve small pool={pool} path={}: {:.0} us/req",
                fast_path.as_str(),
                m.median_s * 1e6
            );
        }
    }
    let median_of = |pool: usize, path: &str| -> f64 {
        medians
            .iter()
            .find(|((p, fp), _)| *p == pool && *fp == path)
            .map(|(_, v)| *v)
            .expect("median recorded")
    };
    let (merged1, composed1) = (median_of(1, "merged"), median_of(1, "composed"));
    let merged_ok = merged1 < composed1;

    // Emit the summary BEFORE asserting: a violated invariant must still
    // upload the numbers that show it.
    let json = Json::obj(vec![
        ("bench", Json::Str("perf_gate".into())),
        ("kernels", Json::Arr(kernel_rows)),
        ("serving", Json::Arr(serving_rows)),
        ("compose_geomean_speedup", Json::Num(compose_geomean)),
        (
            "invariants",
            Json::obj(vec![
                ("fused_le_eager", Json::Bool(compose_ok)),
                ("merged_lt_composed_pool1", Json::Bool(merged_ok)),
            ]),
        ),
    ]);
    let path = std::env::var("DORA_BENCH_JSON")
        .unwrap_or_else(|_| "bench_results/BENCH_ci.json".to_string());
    if let Some(dir) = std::path::Path::new(&path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => println!("perf-gate JSON written to {path}"),
        Err(e) => eprintln!("could not write perf-gate JSON to {path}: {e}"),
    }

    assert!(
        compose_ok,
        "fused compose lost to eager somewhere: speedups {compose_speedups:?}"
    );
    assert!(
        compose_geomean >= 1.1,
        "fused compose geomean speedup {compose_geomean:.2} < 1.1"
    );
    assert!(
        merged_ok,
        "merged fast path not faster at pool=1: merged {merged1:.3e}s vs composed {composed1:.3e}s"
    );
    println!(
        "perf gate OK: compose geomean {compose_geomean:.2}x, merged/composed {:.2}x",
        composed1 / merged1
    );
}
