//! Cost-model bench: regenerates the model-level evaluation — Tables 4, 5,
//! 6, 8/13 and Figures 3, 4, 5 — on the simulated six-GPU testbed.
//! (DESIGN.md §1 explains the substitution; the shapes — who wins, by what
//! factor, where OOMs fall — are the reproduction target.)

use dorafactors::bench::report;

fn main() {
    for id in ["table4", "table6", "table8", "fig4", "fig5"] {
        println!("{}", report::by_name(id).unwrap());
    }
}
