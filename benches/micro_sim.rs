//! Cost-model bench: regenerates the microbenchmark evaluation — Tables
//! 9/14 and Figures 1, 6, 7, 8, 10, 11, 13-15 — on the simulated testbed,
//! plus the g-distribution and dispatch statistics.

use dorafactors::bench::report;

fn main() {
    for id in [
        "table1", "table7", "table9", "fig1", "fig6", "fig7", "fig8", "fig10", "fig11",
        "fig13", "gdist",
    ] {
        println!("{}", report::by_name(id).unwrap());
    }
}
