use dorafactors::runtime::{manifest, Engine, Tensor};
use dorafactors::util::rng::Rng;
use std::time::Instant;

fn main() {
    let dir = manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("(artifacts missing — run `make artifacts` to enable the PJRT profile bench)");
        return;
    }
    let engine = Engine::load(&dir).unwrap();
    let (rows, d_out) = (512usize, 2048usize);
    let mut rng = Rng::new(1);
    let inputs = [
        Tensor::f32(vec![rows, d_out], rng.normal_vec_f32(rows * d_out, 1.0)),
        Tensor::f32(vec![rows, d_out], rng.normal_vec_f32(rows * d_out, 0.3)),
        Tensor::f32(vec![d_out], rng.normal_vec_f32(d_out, 0.01)),
    ];
    for name in ["compose_eager_512x2048", "compose_fused_512x2048"] {
        let exe = engine.executable(name).unwrap();
        // Pre-build literals once.
        let lits: Vec<xla::Literal> = inputs.iter().map(|t| {
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(t.as_f32().unwrap()).reshape(&dims).unwrap()
        }).collect();
        for _ in 0..3 { let _ = exe.execute::<xla::Literal>(&lits).unwrap(); }
        let t0 = Instant::now();
        let n = 10;
        for _ in 0..n {
            let r = exe.execute::<xla::Literal>(&lits).unwrap();
            std::hint::black_box(&r);
        }
        let exec_only = t0.elapsed().as_secs_f64() / n as f64;
        // Now with output download:
        let t0 = Instant::now();
        for _ in 0..n {
            let r = exe.execute::<xla::Literal>(&lits).unwrap();
            let lit = r[0][0].to_literal_sync().unwrap();
            std::hint::black_box(&lit);
        }
        let with_dl = t0.elapsed().as_secs_f64() / n as f64;
        // Literal construction cost:
        let t0 = Instant::now();
        for _ in 0..n {
            let l: Vec<xla::Literal> = inputs.iter().map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.as_f32().unwrap()).reshape(&dims).unwrap()
            }).collect();
            std::hint::black_box(&l);
        }
        let lit_cost = t0.elapsed().as_secs_f64() / n as f64;
        println!("{name}: exec {:.2} ms | +download {:.2} ms | literal-build {:.2} ms",
            exec_only*1e3, with_dl*1e3, lit_cost*1e3);
    }
}
