//! Serving example: train an adapter briefly, hand the adapted parameters
//! to the batched inference server (Tier-2 fused forward), then fire
//! concurrent client traffic and report latency/throughput/occupancy —
//! the paper's deployment context (§6.1) in miniature.
//!
//! Runs on the default execution backend: PJRT when AOT artifacts are
//! usable, the native kernel-registry engine otherwise — so a fresh
//! checkout with no `artifacts/` directory completes end to end.
//!
//! Run with:
//!   cargo run --release --example serve -- \
//!       [--config small] [--train-steps 20] [--clients 8] [--requests 64]

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use dorafactors::coordinator::data::MarkovCorpus;
use dorafactors::coordinator::{Server, ServerCfg, Trainer, TrainerCfg};
use dorafactors::runtime::BackendSpec;
use dorafactors::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let config = args.get_or("config", "small").to_string();
    let train_steps = args.get_usize("train-steps", 20);
    let n_clients = args.get_usize("clients", 8);
    let n_requests = args.get_usize("requests", 64);

    let spec = BackendSpec::auto();
    let backend = spec.connect()?;
    let info = backend.config(&config)?;
    println!("execution backend: {} ({})", backend.kind_name(), backend.platform());

    // --- phase 1: fine-tune the adapter -----------------------------------
    println!("== phase 1: training {train_steps} steps on config {config} ==");
    let mut tr = Trainer::new(
        backend,
        TrainerCfg {
            config: config.clone(),
            variant: "fused".into(),
            seed: 7,
            branching: 4,
            eval_every: 0,
        },
    )?;
    tr.train_steps(train_steps)?;
    println!(
        "trained: loss {:.4} -> {:.4}",
        tr.history.first().unwrap().loss,
        tr.history.last().unwrap().loss
    );

    // --- phase 2: serve with the adapted parameters ------------------------
    println!("\n== phase 2: serving with {n_clients} clients x {n_requests} requests ==");
    let server = Server::start_with_params(
        spec,
        ServerCfg { config: config.clone(), max_wait: Duration::from_millis(5) },
        tr.frozen().to_vec(),
        tr.trainable().to_vec(),
    )?;
    let client = server.client();

    let t0 = Instant::now();
    // Distribute requests across clients WITHOUT dropping the remainder:
    // client `cid` serves base + 1 extra while cid < remainder, so e.g.
    // --requests 65 --clients 8 really serves 65, not 64.
    let base = n_requests / n_clients.max(1);
    let remainder = n_requests % n_clients.max(1);
    let vocab = info.vocab;
    let counter = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let handles: Vec<_> = (0..n_clients)
        .map(|cid| {
            let c = client.clone();
            let counter = counter.clone();
            let quota = base + usize::from(cid < remainder);
            std::thread::spawn(move || -> Result<()> {
                let mut corpus = MarkovCorpus::new(vocab, 4, 1000 + cid as u64);
                for _ in 0..quota {
                    let prompt_len = 8 + (cid % 5) * 3;
                    let prompt = corpus.sequence(prompt_len);
                    let reply = c.infer(&prompt)?;
                    counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let _ = reply;
                }
                Ok(())
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();

    println!(
        "served {} requests in {} batches over {:.2} s ({} failed)",
        m.completed, m.batches, wall, m.failed
    );
    println!(
        "throughput: {:.1} req/s | latency p50 {:.1} ms, p95 {:.1} ms | mean batch occupancy {:.2}/{}",
        m.completed as f64 / wall,
        m.p50_us() / 1e3,
        m.p95_us() / 1e3,
        m.mean_occupancy(),
        info.train_batch
    );
    assert_eq!(
        m.completed as usize, n_requests,
        "request-count shortfall: served {} of {n_requests}",
        m.completed
    );
    assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), n_requests);
    println!("\nserve OK");
    Ok(())
}
