//! Serving example: train an adapter briefly, checkpoint it to an
//! adapter store, then host it NEXT TO a second (fresh-init) adapter on
//! one batched inference server — per-request adapter routing, periodic
//! checkpoint hot-loading, and per-adapter metrics: the paper's
//! multi-adapter deployment context (§6.1) in miniature.
//!
//! Runs on the default execution backend: PJRT when AOT artifacts are
//! usable, the native kernel-registry engine otherwise — so a fresh
//! checkout with no `artifacts/` directory completes end to end.
//!
//! Run with:
//!   cargo run --release --example serve -- \
//!       [--config small] [--train-steps 20] [--clients 8] [--requests 64] \
//!       [--workers 0 (= all cores)] [--fast-path merged|composed] \
//!       [--store DIR]
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use dorafactors::coordinator::data::MarkovCorpus;
use dorafactors::coordinator::{FastPath, Server, ServerCfg, Trainer, TrainerCfg};
use dorafactors::runtime::{Adapter, AdapterStore, BackendSpec, InitReq, Precision};
use dorafactors::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let config = args.get_or("config", "small").to_string();
    let train_steps = args.get_usize("train-steps", 20);
    let n_clients = args.get_usize("clients", 8);
    let n_requests = args.get_usize("requests", 64);
    let workers = args.get_usize("workers", 0);
    let fast_path = FastPath::parse(args.get_or("fast-path", "merged"))?;
    let store_dir = args
        .get("store")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::env::temp_dir().join("dora_serve_example"));
    let store = AdapterStore::open(&store_dir)?;

    let spec = BackendSpec::auto();
    let backend = spec.connect()?;
    let info = backend.config(&config)?;
    println!("execution backend: {} ({})", backend.kind_name(), backend.platform());

    // --- phase 1: fine-tune the "tuned" adapter, checkpoint as we go ------
    println!("== phase 1: training {train_steps} steps on config {config} ==");
    let mut tr = Trainer::new(
        backend.clone(),
        TrainerCfg {
            config: config.clone(),
            variant: "fused".into(),
            seed: 7,
            branching: 4,
            eval_every: 0,
            train_workers: 0,
            grad_accum: 1,
            precision: Precision::F32,
        },
    )?;
    let ckpt_every = (train_steps / 2).max(1);
    tr.set_checkpointing(store.clone(), "tuned", ckpt_every)?;
    tr.train_steps(train_steps)?;
    store.save(&tr.to_adapter("tuned")?)?;
    println!(
        "trained: loss {:.4} -> {:.4} ({} periodic checkpoints -> {:?})",
        tr.history.first().unwrap().loss,
        tr.history.last().unwrap().loss,
        tr.checkpoints_written,
        store.dir()
    );

    // --- phase 2: serve "tuned" alongside an untrained "base" adapter -----
    println!("\n== phase 2: serving 2 adapters, {n_clients} clients x {n_requests} requests ==");
    let base_init =
        backend.init(InitReq { config: config.clone(), seed: 1234, precision: Precision::F32 })?;
    let adapters = vec![
        store.load("tuned")?,
        Adapter::new("base", &info, 1234, 0, base_init.params)?,
    ];
    let server = Server::start_with_adapters(
        spec,
        ServerCfg {
            config: config.clone(),
            max_wait: Duration::from_millis(5),
            workers,
            fast_path,
            queue_depth: 64,
            ..ServerCfg::default()
        },
        adapters,
    )?;
    println!(
        "serving pool: {} workers, {} fast path",
        server.metrics().workers,
        server.fast_path().as_str()
    );
    let client = server.client();
    let names = ["tuned", "base"];

    let t0 = Instant::now();
    // Distribute requests across clients WITHOUT dropping the remainder:
    // client `cid` serves base + 1 extra while cid < remainder, so e.g.
    // --requests 65 --clients 8 really serves 65, not 64. Each request
    // alternates between the two adapters.
    let base = n_requests / n_clients.max(1);
    let remainder = n_requests % n_clients.max(1);
    let vocab = info.vocab;
    let counter = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let handles: Vec<_> = (0..n_clients)
        .map(|cid| {
            let c = client.clone();
            let counter = counter.clone();
            let quota = base + usize::from(cid < remainder);
            std::thread::spawn(move || -> Result<()> {
                let mut corpus = MarkovCorpus::new(vocab, 4, 1000 + cid as u64);
                for i in 0..quota {
                    let prompt_len = 8 + (cid % 5) * 3;
                    let prompt = corpus.sequence(prompt_len);
                    let adapter = names[(cid + i) % names.len()];
                    let reply = c.infer_with(adapter, &prompt)?;
                    assert_eq!(reply.adapter, adapter);
                    counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                Ok(())
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();

    // --- phase 3: hot-load a refreshed checkpoint while serving -----------
    tr.train_steps(train_steps + ckpt_every)?;
    store.save(&tr.to_adapter("tuned")?)?;
    server.hot_load(&store, "tuned")?;
    let refreshed = client.infer_with("tuned", &[1, 2, 3])?;
    println!(
        "\nhot-loaded refreshed \"tuned\" checkpoint (step {}); next_token={}",
        tr.step_count(),
        refreshed.next_token
    );

    let m = server.shutdown();
    println!(
        "served {} requests in {} engine calls over {:.2} s ({} failed, {} hot-loads, \
         {} merged / {} composed batches)",
        m.completed, m.batches, wall, m.failed, m.hot_loads, m.merged_batches, m.composed_batches
    );
    println!(
        "throughput: {:.1} req/s | latency p50 {:.1} ms, p95 {:.1} ms | mean batch occupancy {:.2}/{}",
        m.completed as f64 / wall,
        m.p50_us() / 1e3,
        m.p95_us() / 1e3,
        m.mean_occupancy(),
        info.train_batch
    );
    for (name, am) in &m.per_adapter {
        println!(
            "  adapter {:6} completed {:4} failed {:3} engine calls {:4} p95 {:8.1} ms occupancy {:.2}",
            name,
            am.completed,
            am.failed,
            am.batches,
            am.p95_us() / 1e3,
            am.mean_occupancy()
        );
    }
    for (i, w) in m.per_worker.iter().enumerate() {
        println!(
            "  worker {:3} engine calls {:5} completed {:5} failed {:3}",
            i, w.batches, w.completed, w.failed
        );
    }
    assert_eq!(
        m.completed as usize,
        n_requests + 1, // + the post-hot-load probe
        "request-count shortfall: served {} of {}",
        m.completed,
        n_requests + 1
    );
    assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), n_requests);
    assert_eq!(m.hot_loads, 1);
    println!("\nserve OK");
    Ok(())
}
