//! End-to-end training driver — proves all three layers compose on a real
//! workload: the ~100M-parameter `e2e` transformer, DoRA-adapted on every
//! projection, trained on a synthetic Markov corpus with the fused
//! (Pallas + factored-norm) pipeline — through AOT artifacts when they
//! are available, the native kernel-registry engine otherwise.
//!
//! Logs the loss curve (recorded in EXPERIMENTS.md) and reports tokens/s.
//!
//! Run with:
//!   cargo run --release --example train_e2e -- \
//!       [--config e2e] [--steps 200] [--seed 0] [--eval-every 25]
//!       [--variant fused] [--csv losses.csv]
//!       [--adapter NAME] [--checkpoint-every N] [--store DIR]
//!
//! With `--adapter NAME` the run materializes as a named adapter:
//! periodic checkpoints land in the store (hot-loadable by a running
//! server) and the final parameters are saved under NAME.

use std::fmt::Write as _;

use anyhow::Result;

use dorafactors::coordinator::{Trainer, TrainerCfg};
use dorafactors::runtime::{AdapterStore, ExecBackend};
use dorafactors::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let config = args.get_or("config", "e2e").to_string();
    let steps = args.get_usize("steps", 200);
    let eval_every = args.get_usize("eval-every", 25);
    let variant = args.get_or("variant", "fused").to_string();
    let csv_path = args.get("csv").map(str::to_string);
    let adapter_name = args.get("adapter").map(str::to_string);
    let ckpt_every = args.get_usize("checkpoint-every", 0);

    let engine = ExecBackend::auto();
    let info = engine.config(&config)?;
    let tokens_per_step = info.train_batch * (info.seq + 1);
    println!(
        "== e2e training: {} params, vocab {}, d_model {}, {} layers, r={}, variant={}, backend={} ==",
        info.n_params,
        info.vocab,
        info.d_model,
        info.n_layers,
        info.rank,
        variant,
        engine.kind_name()
    );
    println!(
        "{} steps x {} tokens/step = {} tokens total\n",
        steps,
        tokens_per_step,
        steps * tokens_per_step
    );

    let mut tr = Trainer::new(
        engine,
        TrainerCfg {
            config,
            variant,
            seed: args.get_u64("seed", 0),
            branching: 4,
            eval_every,
        },
    )?;
    println!("corpus entropy floor: (branching 4 Markov chain)");

    let store = match &adapter_name {
        Some(name) => {
            // Validate the name BEFORE training: with no periodic
            // checkpoints the only save happens after the full run, and
            // an invalid name would discard every step of it.
            dorafactors::runtime::adapters::validate_name(name)?;
            let store = AdapterStore::open_or_default(args.get("store"))?;
            if ckpt_every > 0 {
                tr.set_checkpointing(store.clone(), name.clone(), ckpt_every)?;
                println!(
                    "checkpointing adapter {name:?} every {ckpt_every} steps -> {:?}",
                    store.dir()
                );
            }
            Some(store)
        }
        None => None,
    };

    let t0 = std::time::Instant::now();
    let mut csv = String::from("step,loss\n");
    while tr.step_count() < steps {
        let recs: Vec<_> = tr.run_chunk()?.to_vec();
        for r in &recs {
            let _ = writeln!(csv, "{},{:.6}", r.step, r.loss);
        }
        let last = recs.last().unwrap();
        let elapsed = t0.elapsed().as_secs_f64();
        let tok_s = tr.step_count() as f64 * tokens_per_step as f64 / elapsed;
        println!(
            "step {:5} / {steps}  loss {:.4}  | {:7.0} tok/s  ({:.0} s elapsed)",
            last.step, last.loss, tok_s, elapsed
        );
    }

    let first = tr.history.first().unwrap().loss;
    let last = tr.history.last().unwrap().loss;
    let final_eval = tr.eval()?;
    println!("\nloss: {first:.4} -> {last:.4} over {} steps", tr.step_count());
    println!("final eval loss: {final_eval:.4}");
    println!(
        "engine wall time: {:.1} s ({:.2} s/step, {:.0} tok/s)",
        tr.wall_seconds,
        tr.wall_seconds / tr.step_count() as f64,
        tr.step_count() as f64 * tokens_per_step as f64 / tr.wall_seconds
    );
    if !tr.eval_history.is_empty() {
        println!("\neval curve:");
        for r in &tr.eval_history {
            println!("  step {:5}  eval loss {:.4}", r.step, r.loss);
        }
    }
    if let Some(path) = csv_path {
        std::fs::write(&path, csv)?;
        println!("loss curve written to {path}");
    }
    if let (Some(name), Some(store)) = (&adapter_name, &store) {
        let path = store.save(&tr.to_adapter(name)?)?;
        println!(
            "saved adapter {name:?} at step {} -> {path:?} ({} periodic checkpoints)",
            tr.step_count(),
            tr.checkpoints_written
        );
    }
    assert!(last < first, "loss did not decrease — e2e run failed");
    println!("\ntrain_e2e OK");
    Ok(())
}
