//! End-to-end training driver — proves all three layers compose on a real
//! workload: the ~100M-parameter `e2e` transformer, DoRA-adapted on every
//! projection, trained on a synthetic Markov corpus with the fused
//! (Pallas + factored-norm) pipeline — through AOT artifacts when they
//! are available, the native kernel-registry engine otherwise.
//!
//! Logs the loss curve (recorded in EXPERIMENTS.md) and reports tokens/s.
//!
//! Run with:
//!   cargo run --release --example train_e2e -- \
//!       [--config e2e] [--steps 200] [--seed 0] [--eval-every 25]
//!       [--variant fused] [--branching 4] [--csv losses.csv]
//!       [--adapter NAME] [--checkpoint-every N] [--store DIR]
//!       [--train-workers N] [--grad-accum K] [--golden FIXTURE.json]
//!
//! With `--adapter NAME` the run materializes as a named adapter:
//! periodic checkpoints land in the store (hot-loadable by a running
//! server) and the final parameters are saved under NAME.
//!
//! With `--train-workers N` gradients are computed data-parallel over an
//! engine pool (deterministically reduced — the trace is identical for
//! any N); `--grad-accum K` accumulates K micro-steps per optimizer
//! update (effective batch K x train_batch). `--golden FIXTURE.json`
//! asserts the emitted loss prefix against a committed golden trace —
//! the CI data-parallel smoke runs exactly that.

use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

use dorafactors::coordinator::{Trainer, TrainerCfg};
use dorafactors::runtime::{AdapterStore, BackendSpec, ExecBackend, Precision};
use dorafactors::util::json;
use dorafactors::util::Args;

/// Assert the run's loss prefix against a committed golden fixture (the
/// fixture may hold more steps than the run emitted — the overlap must
/// match at the fixture's tolerance, and run metadata must agree).
fn check_golden(path: &str, cfg: &TrainerCfg, losses: &[f32]) -> Result<()> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let fixture = match json::parse(&text) {
        Ok(v) => v,
        Err(e) => bail!("parsing golden fixture {path}: {e:?}"),
    };
    for (key, got) in [
        ("config", cfg.config.as_str()),
        ("variant", cfg.variant.as_str()),
    ] {
        let want = fixture
            .opt(key)
            .and_then(|v| v.as_str().ok().map(str::to_string))
            .with_context(|| format!("golden fixture {path} lacks {key:?}"))?;
        if want != got {
            bail!("golden fixture {path} is for {key}={want}, run has {key}={got}");
        }
    }
    for (key, got) in [
        ("seed", cfg.seed as f64),
        ("branching", cfg.branching as f64),
        ("grad_accum", cfg.grad_accum as f64),
    ] {
        if let Some(want) = fixture.opt(key).and_then(|v| v.as_f64().ok()) {
            if want != got {
                bail!("golden fixture {path} is for {key}={want}, run has {key}={got}");
            }
        }
    }
    let tol = fixture
        .opt("tolerance")
        .and_then(|v| v.as_f64().ok())
        .with_context(|| format!("golden fixture {path} lacks \"tolerance\""))?;
    let entries = fixture
        .opt("losses")
        .and_then(|v| v.as_arr().ok())
        .with_context(|| format!("golden fixture {path} lacks \"losses\""))?;
    let mut want = Vec::with_capacity(entries.len());
    for (i, v) in entries.iter().enumerate() {
        // A non-numeric entry must FAIL the check, not compare as NaN
        // (every NaN comparison is false, which would silently pass).
        match v.as_f64() {
            Ok(x) if x.is_finite() => want.push(x),
            _ => bail!("golden fixture {path}: losses[{i}] is not a finite number"),
        }
    }
    let n = losses.len().min(want.len());
    if n == 0 {
        bail!("golden fixture {path} has no overlap with the emitted losses");
    }
    for i in 0..n {
        let diff = (losses[i] as f64 - want[i]).abs();
        if diff > tol {
            bail!(
                "golden trace diverged at step {}: loss {} vs fixture {} (|d| = {diff:.3e} > {tol:.1e})",
                i + 1,
                losses[i],
                want[i]
            );
        }
    }
    println!("golden check OK: {n} steps within {tol:.1e} of {path}");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let config = args.get_or("config", "e2e").to_string();
    let steps = args.get_usize("steps", 200);
    let eval_every = args.get_usize("eval-every", 25);
    let variant = args.get_or("variant", "fused").to_string();
    let branching = args.get_usize("branching", 4);
    let csv_path = args.get("csv").map(str::to_string);
    let adapter_name = args.get("adapter").map(str::to_string);
    let ckpt_every = args.get_usize("checkpoint-every", 0);
    let train_workers = args.get_usize("train-workers", 0);
    let grad_accum = args.get_usize("grad-accum", 1);
    let golden = args.get("golden").map(str::to_string);

    let cfg = TrainerCfg {
        config,
        variant,
        seed: args.get_u64("seed", 0),
        branching,
        eval_every,
        train_workers,
        grad_accum,
        precision: Precision::parse(args.get_or("precision", "f32"))?,
    };
    // One construction path owns every engine connection: the trainer's
    // backend (and, data-parallel, its worker pool) — no throwaway
    // banner-only backend.
    let mut tr = if train_workers > 0 {
        Trainer::with_spec(&BackendSpec::auto(), cfg.clone())?
    } else {
        Trainer::new(ExecBackend::auto(), cfg.clone())?
    };
    let info = tr.config_info().clone();
    let tokens_per_step = grad_accum * info.train_batch * (info.seq + 1);
    println!(
        "== e2e training: {} params, vocab {}, d_model {}, {} layers, r={}, variant={}, backend={} ==",
        info.n_params,
        info.vocab,
        info.d_model,
        info.n_layers,
        info.rank,
        cfg.variant,
        tr.backend_kind()
    );
    println!(
        "{} steps x {} tokens/step = {} tokens total ({} gradient workers, accum {})\n",
        steps,
        tokens_per_step,
        steps * tokens_per_step,
        train_workers.max(1),
        grad_accum
    );
    println!("corpus entropy floor: (branching {branching} Markov chain)");

    let store = match &adapter_name {
        Some(name) => {
            // Validate the name BEFORE training: with no periodic
            // checkpoints the only save happens after the full run, and
            // an invalid name would discard every step of it.
            dorafactors::runtime::adapters::validate_name(name)?;
            let store = AdapterStore::open_or_default(args.get("store"))?;
            if ckpt_every > 0 {
                tr.set_checkpointing(store.clone(), name.clone(), ckpt_every)?;
                println!(
                    "checkpointing adapter {name:?} every {ckpt_every} steps -> {:?}",
                    store.dir()
                );
            }
            Some(store)
        }
        None => None,
    };

    let t0 = std::time::Instant::now();
    let mut csv = String::from("step,loss\n");
    while tr.step_count() < steps {
        let recs: Vec<_> = tr.run_chunk()?.to_vec();
        for r in &recs {
            let _ = writeln!(csv, "{},{:.6}", r.step, r.loss);
        }
        let last = recs.last().unwrap();
        let elapsed = t0.elapsed().as_secs_f64();
        let tok_s = tr.step_count() as f64 * tokens_per_step as f64 / elapsed;
        println!(
            "step {:5} / {steps}  loss {:.4}  | {:7.0} tok/s  ({:.0} s elapsed)",
            last.step, last.loss, tok_s, elapsed
        );
    }

    let first = tr.history.first().unwrap().loss;
    let last = tr.history.last().unwrap().loss;
    let final_eval = tr.eval()?;
    println!("\nloss: {first:.4} -> {last:.4} over {} steps", tr.step_count());
    println!("final eval loss: {final_eval:.4}");
    println!(
        "engine wall time: {:.1} s ({:.2} s/step, {:.0} tok/s)",
        tr.wall_seconds,
        tr.wall_seconds / tr.step_count() as f64,
        tr.step_count() as f64 * tokens_per_step as f64 / tr.wall_seconds
    );
    if !tr.eval_history.is_empty() {
        println!("\neval curve:");
        for r in &tr.eval_history {
            println!("  step {:5}  eval loss {:.4}", r.step, r.loss);
        }
    }
    if let Some(path) = csv_path {
        std::fs::write(&path, csv)?;
        println!("loss curve written to {path}");
    }
    if let (Some(name), Some(store)) = (&adapter_name, &store) {
        let path = store.save(&tr.to_adapter(name)?)?;
        println!(
            "saved adapter {name:?} at step {} -> {path:?} ({} periodic checkpoints)",
            tr.step_count(),
            tr.checkpoints_written
        );
    }
    if let Some(path) = &golden {
        let losses: Vec<f32> = tr.history.iter().map(|r| r.loss).collect();
        check_golden(path, &cfg, &losses)?;
    }
    assert!(last < first, "loss did not decrease — e2e run failed");
    println!("\ntrain_e2e OK");
    Ok(())
}
