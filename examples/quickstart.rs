//! Quickstart: the three-layer stack end to end in one page.
//!
//! 1. Connect the execution backend — PJRT over the AOT artifacts when
//!    usable (`make artifacts`), the native kernel-registry engine
//!    otherwise (a fresh checkout completes without any artifacts).
//! 2. Run one DoRA linear module under all four configurations and
//!    confirm they agree numerically.
//! 3. Cross-check the engine's compose unit against the flat Rust CPU
//!    kernels (on PJRT this is a cross-layer XLA check; on the native
//!    engine it exercises the artifact-surface plumbing).
//! 4. Show the three-tier dispatch decisions for a real model inventory.
//!
//! Run with: `cargo run --release --example quickstart`

use anyhow::Result;

use dorafactors::dispatch::{self, ComposeCtx, DispatchEnv};
use dorafactors::dora::config::{ActShape, ModuleShape};
use dorafactors::dora::{compose_cpu, norm_cpu};
use dorafactors::models;
use dorafactors::runtime::{
    ComposeReq, DoraLinearReq, ExecBackend, LinearVariant, Tensor, Variant,
};
use dorafactors::util::rng::Rng;

fn main() -> Result<()> {
    println!("== dorafactors quickstart ==\n");
    let engine = ExecBackend::auto();
    println!("execution backend: {} ({})", engine.kind_name(), engine.platform());

    // --- one adapted module through all four configurations --------------
    let (bs, sq, d, r) = (2usize, 64usize, 256usize, 32usize);
    let mut rng = Rng::new(42);
    let x = rng.normal_vec_f32(bs * sq * d, 1.0);
    let w = rng.normal_vec_f32(d * d, 0.05);
    let a = rng.normal_vec_f32(r * d, 0.06);
    let b = rng.normal_vec_f32(d * r, 0.06);
    // DoRA magnitude: start from the composed row norms so g is near 1.
    let s = 16.0 / (r as f32).sqrt();
    let mut tracker = norm_cpu::AllocTracker::new();
    let m =
        norm_cpu::factored_norm(&w, &a, &b, s, ModuleShape::new(d, d, r), 1 << 20, &mut tracker);

    // The typed op surface: one request struct per adapted module —
    // shapes are named fields, not positional slots.
    let mut reference: Option<Vec<f32>> = None;
    for variant in LinearVariant::ALL {
        let resp = engine.dora_linear(DoraLinearReq {
            variant,
            x: Tensor::f32(vec![bs, sq, d], x.clone()),
            w: Tensor::f32(vec![d, d], w.clone()),
            a: Tensor::f32(vec![r, d], a.clone()),
            b: Tensor::f32(vec![d, r], b.clone()),
            mag: Tensor::f32(vec![d], m.clone()),
        })?;
        let y = resp.y.as_f32()?.to_vec();
        let mean_abs: f32 = y.iter().map(|v| v.abs()).sum::<f32>() / y.len() as f32;
        let variant = variant.as_str();
        match &reference {
            None => {
                println!("dora_linear[{variant:9}] mean|y| = {mean_abs:.4}  (reference)");
                reference = Some(y);
            }
            Some(r0) => {
                let max_diff = y
                    .iter()
                    .zip(r0)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                println!("dora_linear[{variant:9}] mean|y| = {mean_abs:.4}  max|Δ| vs peft = {max_diff:.2e}");
                assert!(max_diff < 1e-3, "configurations disagree");
            }
        }
    }

    // --- cross-layer check: engine compose op vs Rust CPU kernel ----------
    let act = ActShape::new(512, 2048);
    let base = rng.normal_vec_f32(act.elems(), 1.0);
    let lora = rng.normal_vec_f32(act.elems(), 0.3);
    let g: Vec<f32> = (0..act.d_out).map(|_| 1.0 + rng.normal() as f32 * 0.002).collect();
    let engine_out = engine.compose(ComposeReq {
        variant: Variant::Fused,
        base: Tensor::f32(vec![512, 2048], base.clone()),
        lora: Tensor::f32(vec![512, 2048], lora.clone()),
        g: Tensor::f32(vec![2048], g.clone()),
    })?;
    let cpu_out = compose_cpu::compose_fused(&base, &lora, &g, 2.0, act);
    let max_diff = engine_out
        .delta
        .as_f32()?
        .iter()
        .zip(&cpu_out)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("\ncompose: engine op vs Rust CPU kernel max|Δ| = {max_diff:.2e}");
    assert!(max_diff < 1e-4);

    // --- dispatch over a real model inventory ------------------------------
    let env = DispatchEnv::default();
    let spec = models::find("Qwen3-VL-8B").unwrap();
    println!("\ndispatch (training, bs=1 x seq=4096, r=384) for {}:", spec.name);
    for (proj, shape, count) in spec.inventory(384) {
        // The kernel-registry dispatch surface: tier + runnable backend.
        let choice =
            dispatch::select_kernel(&env, &ComposeCtx::training(ActShape::new(4096, shape.d_out)));
        println!(
            "  {:10} [{}x{}] x{count}: {} via {}",
            proj.name(),
            shape.d_out,
            shape.d_in,
            choice.tier.name(),
            choice.backend.name()
        );
    }
    let stats = dispatch::model_tier_stats(&env, spec, 384, 4096);
    println!(
        "  => {:.0}% of modules on Tier 1 (paper: ~71%)",
        100.0 * stats.frac_tier1()
    );

    println!("\nquickstart OK");
    Ok(())
}
