//! Convergence equivalence study — paper §5.9, Table 10 / Figure 12.
//!
//! Trains the `small` config (multi-seed) under BOTH numeric paths (eager
//! vs fused) from identical seeds and data streams, then reports per-step
//! |Δloss| statistics and final eval-loss agreement, plus wall-clock per
//! path. Loss curves are written as CSV for plotting.
//!
//! Run with:
//!   cargo run --release --example convergence -- \
//!       [--steps 300] [--seeds 3] [--config small] [--csv out.csv]

use std::fmt::Write as _;

use anyhow::Result;

use dorafactors::coordinator::{Trainer, TrainerCfg};
use dorafactors::runtime::{ExecBackend, Precision};
use dorafactors::util::table::Table;
use dorafactors::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 300);
    let n_seeds = args.get_u64("seeds", 3);
    let config = args.get_or("config", "small").to_string();
    let csv_path = args.get("csv").map(str::to_string);

    let engine = ExecBackend::auto();
    let info = engine.config(&config)?;
    println!(
        "== convergence study: config={config} ({} params, {} backend), {steps} steps x {n_seeds} seeds x (eager, fused) ==",
        info.n_params,
        engine.kind_name()
    );

    let mut table = Table::new(
        "Table 10 (reproduced) — eager vs fused training-loss deltas",
        &["Seed", "Steps", "Mean |Δ|", "Max |Δ|", "Eval |Δ|", "Wall fused/eager (s)"],
    );
    let mut csv = String::from("seed,step,loss_eager,loss_fused\n");
    let mut grand_mean = Vec::new();
    let mut grand_eval = Vec::new();

    for seed in 0..n_seeds {
        let run = |variant: &str| -> Result<Trainer> {
            let mut tr = Trainer::new(
                engine.clone(),
                TrainerCfg {
                    config: config.clone(),
                    variant: variant.into(),
                    seed,
                    branching: 4,
                    eval_every: 0,
                    train_workers: 0,
                    grad_accum: 1,
                    precision: Precision::F32,
                },
            )?;
            tr.train_steps(steps)?;
            Ok(tr)
        };
        let eager = run("eager")?;
        let fused = run("fused")?;

        let (mean_d, max_d) = Trainer::loss_delta(&eager, &fused);
        let eval_e = eager.eval()?;
        let eval_f = fused.eval()?;
        let eval_d = (eval_e - eval_f).abs() as f64;
        grand_mean.push(mean_d);
        grand_eval.push(eval_d);

        for (a, b) in eager.history.iter().zip(&fused.history) {
            let _ = writeln!(csv, "{seed},{},{:.6},{:.6}", a.step, a.loss, b.loss);
        }

        println!(
            "seed {seed}: first loss {:.4} -> last {:.4} (eager) | mean|Δ| {mean_d:.2e}, max|Δ| {max_d:.2e}, eval|Δ| {eval_d:.2e}",
            eager.history.first().unwrap().loss,
            eager.history.last().unwrap().loss,
        );
        table.row(vec![
            seed.to_string(),
            steps.to_string(),
            format!("{mean_d:.2e}"),
            format!("{max_d:.2e}"),
            format!("{eval_d:.2e}"),
            format!("{:.1}/{:.1}", fused.wall_seconds, eager.wall_seconds),
        ]);
    }

    let gm = dorafactors::util::stats::mean(&grand_mean);
    let ge = dorafactors::util::stats::mean(&grand_eval);
    table.row(vec![
        "grand".into(),
        "".into(),
        format!("{gm:.2e}"),
        "".into(),
        format!("{ge:.2e}"),
        "".into(),
    ]);
    println!("\n{}", table.to_markdown());
    println!("(paper, 2000 steps on Qwen3.5-9B: grand mean 7.1e-4, eval 8.9e-5)");

    if let Some(path) = csv_path {
        std::fs::write(&path, csv)?;
        println!("loss curves written to {path}");
    }
    Ok(())
}
