//! Streaming-decode example and CI smoke: token-by-token autoregressive
//! generation through the continuous-batching scheduler, exercising the
//! full lifecycle the paper's serving context (§6.1) needs on top of
//! batch inference — mixed-length concurrent streams joining and leaving
//! the running batch, the greedy determinism contract under load, typed
//! `Overloaded` backpressure at saturation, and the TTFT / per-token SLO
//! histograms.
//!
//! Runs on the native kernel-registry engine (the scheduler is
//! backend-agnostic, but the smoke must complete on a fresh checkout
//! with no `artifacts/` directory).
//!
//! Run with:
//!   cargo run --release --example stream -- \
//!       [--config tiny] [--clients 6] [--streams 4] [--max-tokens 24] \
//!       [--workers 2] [--fast-path merged|composed] [--queue-depth 32] \
//!       [--p99-ms 250]
use std::time::{Duration, Instant};

use anyhow::Result;

use dorafactors::coordinator::{FastPath, GenOptions, Overloaded, Server, ServerCfg};
use dorafactors::runtime::ops::AdapterVariant;
use dorafactors::runtime::{Adapter, BackendSpec, ExecBackend, InitReq, Precision};
use dorafactors::util::Args;

/// Poll `probe` until it holds or `what` times out (scheduler gauges lag
/// submission by a decode step).
fn wait_for(what: &str, mut probe: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !probe() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let config = args.get_or("config", "tiny").to_string();
    let n_clients = args.get_usize("clients", 6);
    let per_client = args.get_usize("streams", 4);
    let max_tokens = args.get_usize("max-tokens", 24).max(4);
    let workers = args.get_usize("workers", 2);
    let fast_path = FastPath::parse(args.get_or("fast-path", "merged"))?;
    let queue_depth = args.get_usize("queue-depth", 32);
    let p99_ms = args.get_usize("p99-ms", 250) as f64;

    let be = ExecBackend::native();
    let info = be.config(&config)?;
    let cfg = |queue_depth| ServerCfg {
        config: config.clone(),
        max_wait: Duration::from_millis(2),
        workers,
        fast_path,
        queue_depth,
        ..ServerCfg::default()
    };
    let adapter = |name: &str, seed: i32, variant| -> Result<Adapter> {
        let init = be.init(InitReq { config: config.clone(), seed, precision: Precision::F32 })?;
        Ok(Adapter::new(name, &info, seed as u64, 0, init.params)?.with_variant(variant))
    };

    // --- phase 1: greedy reference on an idle server ----------------------
    let server = Server::start_with_adapters(
        BackendSpec::Native,
        cfg(queue_depth),
        vec![
            adapter("alice", 1, AdapterVariant::Dora)?,
            adapter("bob", 2, AdapterVariant::Bora)?,
        ],
    )?;
    println!(
        "streaming server: {} workers, {} fast path, queue depth {queue_depth}",
        server.metrics().workers,
        server.fast_path().as_str()
    );
    let client = server.client();
    let probe_prompt = [2, 7, 1, 8];
    let greedy = GenOptions { max_tokens, ..GenOptions::default() };
    let reference = client.generate_collect_with("alice", &probe_prompt, greedy)?;
    assert_eq!(reference.len(), max_tokens);
    println!(
        "greedy reference ({} tokens): {:?}...",
        reference.len(),
        &reference[..4.min(reference.len())]
    );

    // --- phase 2: mixed-length concurrent streams + mid-load probe --------
    println!(
        "\n== {n_clients} clients x {per_client} streams, lengths 4..={max_tokens}, both adapters =="
    );
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|cid| {
            let c = client.clone();
            std::thread::spawn(move || -> Result<usize> {
                let mut tokens = 0usize;
                for i in 0..per_client {
                    // Mixed lengths and adapters; odd streams sample with a
                    // per-stream seed instead of decoding greedily.
                    let want = 4 + (cid * 7 + i * 3) % (max_tokens - 3);
                    let opts = GenOptions {
                        max_tokens: want,
                        temperature: if (cid + i) % 2 == 0 { 0.0 } else { 0.8 },
                        seed: (cid * 100 + i) as u64,
                        ..GenOptions::default()
                    };
                    let name = if (cid + i) % 2 == 0 { "alice" } else { "bob" };
                    let prompt = [cid as i32 + 1, i as i32 + 1];
                    let stream = c.generate_with(name, &prompt, opts)?;
                    let mut got = 0usize;
                    let mut finished = false;
                    for ev in stream {
                        let ev = ev?;
                        assert_eq!(ev.index, got, "out-of-order token event");
                        got += 1;
                        finished = ev.finish.is_some();
                    }
                    assert!(finished, "stream ended without a finish reason");
                    assert_eq!(got, want, "stream token-count shortfall");
                    tokens += got;
                }
                Ok(tokens)
            })
        })
        .collect();
    // While the fleet decodes, re-run the greedy probe mid-batch: the
    // continuous-batching determinism contract says co-resident streams
    // never perturb it.
    let joined = client.generate_collect_with("alice", &probe_prompt, greedy)?;
    assert_eq!(joined, reference, "mid-load greedy decode diverged from idle reference");
    println!("mid-load greedy probe matches the idle reference bitwise");
    let mut total_tokens = joined.len() + reference.len();
    for h in handles {
        total_tokens += h.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let m = server.shutdown();
    let streams = (n_clients * per_client) as u64 + 2;
    println!(
        "decoded {} tokens across {} streams in {:.2} s ({:.0} tok/s, {} engine steps, \
         mean batch {:.2}/{} slots)",
        m.decode_tokens,
        m.decode_completed,
        wall,
        m.decode_tokens as f64 / wall,
        m.decode_tokens as f64 / m.decode_steps.max(1) as f64,
        info.train_batch
    );
    println!(
        "SLO: ttft p50 {:.2} ms p99 {:.2} ms | token p50 {:.2} ms p99 {:.2} ms",
        m.ttft_p50_us() / 1e3,
        m.ttft_p99_us() / 1e3,
        m.token_p50_us() / 1e3,
        m.token_p99_us() / 1e3,
    );
    assert_eq!(m.decode_completed, streams, "a stream was lost");
    assert_eq!(m.decode_failed, 0);
    assert_eq!(m.decode_tokens as usize, total_tokens);
    assert_eq!(m.shed_requests, 0, "traffic within capacity must not shed");
    assert!(
        m.token_p99_us() / 1e3 < p99_ms,
        "token p99 {:.2} ms blew the {p99_ms} ms smoke budget",
        m.token_p99_us() / 1e3
    );

    // --- phase 3: saturation sheds with a typed error, fail-fast ----------
    println!("\n== saturation: fill every slot + a 2-deep queue, expect Overloaded ==");
    let server = Server::start_with_adapters(
        BackendSpec::Native,
        ServerCfg { workers: 1, ..cfg(2) },
        vec![adapter("alice", 1, AdapterVariant::Dora)?],
    )?;
    let client = server.client();
    let endless = GenOptions { max_tokens: usize::MAX, ..GenOptions::default() };
    // Submit the fillers one at a time, waiting for each to be admitted
    // into a slot: a burst could transiently overflow the 2-deep queue
    // and shed a filler instead of the probe below.
    let mut fillers = Vec::new();
    for i in 0..info.train_batch {
        fillers.push(client.generate_with("alice", &[i as i32 + 1], endless)?);
        wait_for("filler admitted", || server.metrics().decode_in_flight == i + 1);
    }
    let queued: Vec<_> = (0..2)
        .map(|_| client.generate_with("alice", &[9], endless))
        .collect::<Result<_>>()?;
    let before = Instant::now();
    let err = client
        .generate_with("alice", &[10], endless)
        .expect_err("submit beyond the queue cap must shed");
    assert!(before.elapsed() < Duration::from_secs(1), "shed was not fail-fast");
    let overloaded = err
        .downcast_ref::<Overloaded>()
        .unwrap_or_else(|| panic!("shed error was not a typed Overloaded: {err:#}"));
    println!("shed with typed Overloaded at queue depth {}", overloaded.queue_depth);
    drop(fillers);
    drop(queued);
    let m = server.shutdown();
    assert_eq!(m.shed_requests, 1);
    assert_eq!(m.decode_in_flight, 0);
    assert_eq!(m.decode_failed, 0);

    println!("\nstream OK");
    Ok(())
}
