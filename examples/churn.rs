//! Churn smoke: multi-tenant serving under a DELIBERATELY small
//! merged-weight budget — more adapters than the cache can hold, mixed
//! one-shot and streaming traffic, and the hot adapter swapped between
//! two parameter sets mid-flight. The native engine is deterministic, so
//! every reply is checked bitwise against per-(adapter, path) references
//! captured on quiescent single-adapter servers before the churn starts.
//!
//! Exit criteria (asserted): the budget forced evictions, zero replies
//! mismatched their reference, zero failed requests or merge builds, and
//! the resident high-water mark never exceeded the budget. Sized for a
//! CI smoke job (~seconds).
//!
//! Run with:
//!   cargo run --release --example churn -- \
//!       [--adapters 8] [--seconds 8] [--clients 3] [--swaps 32] \
//!       [--merge-budget-mb 0.03125 (= 2 tiny merges)]
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use dorafactors::coordinator::{FastPath, GenOptions, Server, ServerCfg, Trainer, TrainerCfg};
use dorafactors::runtime::{Adapter, BackendSpec, ExecBackend, InitReq, Precision};
use dorafactors::util::Args;

const PROMPT: [i32; 4] = [3, 1, 4, 1];
const STREAM_TOKENS: usize = 10;

fn tiny_adapter(be: &ExecBackend, name: &str, seed: i32) -> Result<Adapter> {
    let info = be.config("tiny")?;
    let init = be.init(InitReq { config: "tiny".into(), seed, precision: Precision::F32 })?;
    Adapter::new(name, &info, seed as u64, 0, init.params)
}

/// One-shot logits and greedy stream tokens for one parameter set on one
/// path, from a quiescent single-adapter server.
fn references(adapter: &Adapter, path: FastPath) -> Result<(Vec<f32>, Vec<i32>)> {
    let server = Server::start_with_adapters(
        BackendSpec::Native,
        ServerCfg {
            config: "tiny".into(),
            max_wait: Duration::from_millis(2),
            workers: 1,
            fast_path: path,
            queue_depth: 8,
            ..ServerCfg::default()
        },
        vec![adapter.clone()],
    )?;
    let client = server.client();
    let logits = client.infer_with(&adapter.name, &PROMPT)?.logits;
    let tokens = client.generate_collect_with(
        &adapter.name,
        &PROMPT,
        GenOptions { max_tokens: STREAM_TOKENS, ..GenOptions::default() },
    )?;
    drop(client);
    server.shutdown();
    Ok((logits, tokens))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let n_adapters = args.get_usize("adapters", 8).max(2);
    let seconds = args.get_f64("seconds", 8.0);
    let n_clients = args.get_usize("clients", 3);
    let swaps = args.get_usize("swaps", 32);
    // Default budget: two tiny merges (16 KiB each) — far fewer than the
    // hosted adapters, so eviction churn is guaranteed.
    let budget_mb = args.get_f64("merge-budget-mb", 2.0 * 16.0 / 1024.0);
    let budget = (budget_mb * 1024.0 * 1024.0) as u64;

    let be = ExecBackend::native();
    // Adapter 0 churns between two versions: its seeded init and a
    // briefly-trained replacement (the checkpoint-reload shape).
    let mut adapters = Vec::with_capacity(n_adapters);
    for i in 0..n_adapters {
        adapters.push(tiny_adapter(&be, &format!("a{i}"), i as i32 + 1)?);
    }
    let mut tr = Trainer::with_spec(
        &BackendSpec::Native,
        TrainerCfg {
            config: "tiny".into(),
            variant: "fused".into(),
            seed: 77,
            branching: 3,
            eval_every: 0,
            train_workers: 0,
            grad_accum: 1,
            precision: Precision::F32,
        },
    )?;
    tr.train_steps(4)?;
    let a0_trained = tr.to_adapter("a0")?;

    // Reference book: (adapter name, path) -> logits, plus the stream
    // token sequences adapter 0 may produce (2 versions x 2 paths).
    println!("building references for {n_adapters} adapters x 2 paths...");
    let mut logits_refs: BTreeMap<(String, &'static str), Vec<Vec<f32>>> = BTreeMap::new();
    let mut a0_tokens: Vec<Vec<i32>> = Vec::new();
    for path in [FastPath::Merged, FastPath::Composed] {
        for a in &adapters {
            let (logits, tokens) = references(a, path)?;
            logits_refs.entry((a.name.clone(), path.as_str())).or_default().push(logits);
            if a.name == "a0" {
                a0_tokens.push(tokens);
            }
        }
        let (logits, tokens) = references(&a0_trained, path)?;
        logits_refs.entry(("a0".into(), path.as_str())).or_default().push(logits);
        a0_tokens.push(tokens);
    }
    let logits_refs = Arc::new(logits_refs);
    let a0_tokens = Arc::new(a0_tokens);

    let server = Server::start_with_adapters(
        BackendSpec::Native,
        ServerCfg {
            config: "tiny".into(),
            max_wait: Duration::from_millis(2),
            workers: 2,
            fast_path: FastPath::Merged,
            queue_depth: 16,
            merge_budget: Some(budget),
            ..ServerCfg::default()
        },
        adapters,
    )?;
    println!(
        "churning {n_adapters} adapters under a {:.0} KiB budget for {seconds:.1} s \
         ({n_clients} one-shot clients + 1 streamer + {swaps} hot-swaps)",
        budget as f64 / 1024.0
    );
    let client = server.client();
    let stop = Arc::new(AtomicBool::new(false));
    let mismatches = Arc::new(AtomicUsize::new(0));

    let hammers: Vec<_> = (0..n_clients)
        .map(|tid| {
            let c = client.clone();
            let stop = stop.clone();
            let refs = logits_refs.clone();
            let mismatches = mismatches.clone();
            std::thread::spawn(move || -> Result<usize> {
                let mut served = 0usize;
                let mut i = tid;
                while !stop.load(Ordering::SeqCst) {
                    let name = format!("a{}", i % n_adapters);
                    i += 1;
                    let reply = c.infer_with(&name, &PROMPT)?;
                    let ok = refs[&(name.clone(), reply.path.as_str())]
                        .iter()
                        .any(|r| *r == reply.logits);
                    if !ok {
                        mismatches.fetch_add(1, Ordering::Relaxed);
                    }
                    served += 1;
                }
                Ok(served)
            })
        })
        .collect();
    let streamer = {
        let c = client.clone();
        let stop = stop.clone();
        let a0_tokens = a0_tokens.clone();
        let mismatches = mismatches.clone();
        std::thread::spawn(move || -> Result<usize> {
            let mut streams = 0usize;
            while !stop.load(Ordering::SeqCst) {
                let tokens = c.generate_collect_with(
                    "a0",
                    &PROMPT,
                    GenOptions { max_tokens: STREAM_TOKENS, ..GenOptions::default() },
                )?;
                if !a0_tokens.iter().any(|r| *r == tokens) {
                    mismatches.fetch_add(1, Ordering::Relaxed);
                }
                streams += 1;
            }
            Ok(streams)
        })
    };

    // Churn driver: swap a0 between its two versions across the run.
    let deadline = Instant::now() + Duration::from_secs_f64(seconds);
    let gap = Duration::from_secs_f64(seconds / swaps.max(1) as f64);
    let mut swapped = 0usize;
    while Instant::now() < deadline {
        let params = if swapped % 2 == 0 {
            a0_trained.params.clone()
        } else {
            tiny_adapter(&be, "a0", 1)?.params
        };
        server.load_adapter("a0", params)?;
        swapped += 1;
        if swapped >= swaps {
            std::thread::sleep(deadline.saturating_duration_since(Instant::now()));
            break;
        }
        std::thread::sleep(gap);
    }
    stop.store(true, Ordering::SeqCst);
    let served: usize = hammers.into_iter().map(|h| h.join().unwrap()).sum::<Result<usize>>()?;
    let streams = streamer.join().unwrap()?;
    let m = server.shutdown();

    println!(
        "served {served} one-shots + {streams} streams with {swapped} hot-swaps; \
         cache: {} hits / {} misses, {} promotions, {} evictions, {} rejected, \
         high water {} KiB of {} KiB",
        m.cache_hits,
        m.cache_misses,
        m.cache_promotions,
        m.cache_evictions,
        m.cache_rejects,
        m.cache_high_water_bytes / 1024,
        m.merge_budget_bytes / 1024
    );
    let bad = mismatches.load(Ordering::Relaxed);
    assert_eq!(bad, 0, "{bad} replies matched no quiescent reference");
    assert!(served > 0 && streams > 0, "traffic never flowed");
    assert!(m.cache_evictions > 0, "budget never forced an eviction — smoke proved nothing");
    assert_eq!(m.failed, 0);
    assert_eq!(m.decode_failed, 0);
    assert_eq!(m.merge_fallbacks, 0, "an async merge build failed");
    assert!(m.cache_high_water_bytes <= budget, "budget overshot");
    println!("churn OK");
    Ok(())
}
