//! Offline shim of the `anyhow` error-handling API.
//!
//! The dorafactors build is fully offline (no crates.io access), so the
//! workspace vendors this minimal implementation of the subset of anyhow
//! that the crate uses:
//!
//! * [`Error`] — a boxed-free message chain with `{}` (top message) and
//!   `{:#}` (full `a: b: c` chain) formatting, like anyhow's.
//! * [`Result<T>`] — `std::result::Result<T, Error>`.
//! * [`anyhow!`] / [`bail!`] — format-style constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, wrapping the prior error as the new error's cause.
//!
//! Swapping in the real `anyhow` crate is a one-line Cargo change; every
//! call site in this workspace compiles against either.

use std::fmt;

/// Error type: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap this error as the cause of a new, higher-level message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }

    /// Outermost (most recently attached) message.
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain joined with ": " (anyhow's format).
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(msg)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for (i, msg) in self.chain().skip(1).enumerate() {
                write!(f, "\n    {i}: {msg}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, exactly like
// anyhow's: that keeps the blanket conversion below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Capture the std error's own source chain as message links.
        let mut msgs = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error { msg, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, a displayable value, or a
/// format string with arguments (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_top_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.root_message(), "outer");
        let o: Option<u32> = None;
        assert_eq!(o.context("absent").unwrap_err().root_message(), "absent");
        let some: Option<u32> = Some(3);
        assert_eq!(some.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn context_chains_on_anyhow_error() {
        let base: Result<()> = Err(anyhow!("inner {}", 7));
        let e = base.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: inner 7");
    }

    #[test]
    fn macros_cover_all_arms() {
        fn fails(n: i32) -> Result<()> {
            if n == 0 {
                bail!("zero");
            }
            if n < 0 {
                bail!("negative: {n}");
            }
            Err(anyhow!(io_err()))
        }
        assert_eq!(fails(0).unwrap_err().to_string(), "zero");
        assert_eq!(fails(-2).unwrap_err().to_string(), "negative: -2");
        assert_eq!(fails(1).unwrap_err().to_string(), "missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("x").is_err());
    }
}
