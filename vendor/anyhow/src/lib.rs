//! Offline shim of the `anyhow` error-handling API.
//!
//! The dorafactors build is fully offline (no crates.io access), so the
//! workspace vendors this minimal implementation of the subset of anyhow
//! that the crate uses:
//!
//! * [`Error`] — a boxed-free message chain with `{}` (top message) and
//!   `{:#}` (full `a: b: c` chain) formatting, like anyhow's.
//! * [`Result<T>`] — `std::result::Result<T, Error>`.
//! * [`anyhow!`] / [`bail!`] — format-style constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, wrapping the prior error as the new error's cause.
//!
//! Swapping in the real `anyhow` crate is a one-line Cargo change; every
//! call site in this workspace compiles against either.

use std::fmt;

/// Error type: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    /// The typed value this link was converted from (set by the blanket
    /// `From<E: std::error::Error>` conversion), so `downcast_ref` can
    /// recover the original error type, like real anyhow's.
    typed: Option<Box<dyn std::any::Any + Send + Sync>>,
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), source: None, typed: None }
    }

    /// Construct from a typed std error (recoverable via
    /// [`Error::downcast_ref`]), mirroring `anyhow::Error::new`.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        Error::from(e)
    }

    /// Wrap this error as the cause of a new, higher-level message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)), typed: None }
    }

    /// Walk the cause chain looking for a link converted from a value of
    /// type `E` (mirrors `anyhow::Error::downcast_ref`).
    pub fn downcast_ref<E: fmt::Display + fmt::Debug + Send + Sync + 'static>(
        &self,
    ) -> Option<&E> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(t) = e.typed.as_ref().and_then(|b| b.downcast_ref::<E>()) {
                return Some(t);
            }
            cur = e.source.as_deref();
        }
        None
    }

    /// Is any link in the cause chain a value of type `E`?
    pub fn is<E: fmt::Display + fmt::Debug + Send + Sync + 'static>(&self) -> bool {
        self.downcast_ref::<E>().is_some()
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }

    /// Outermost (most recently attached) message.
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain joined with ": " (anyhow's format).
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(msg)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for (i, msg) in self.chain().skip(1).enumerate() {
                write!(f, "\n    {i}: {msg}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, exactly like
// anyhow's: that keeps the blanket conversion below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Capture the std error's own source chain as message links.
        let mut msgs = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error { msg, source: err.map(Box::new), typed: None });
        }
        let mut err = err.expect("at least one message");
        // The outermost link corresponds to `e` itself: keep the typed
        // value there for downcast_ref.
        err.typed = Some(Box::new(e));
        err
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, a displayable value, or a
/// format string with arguments (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_top_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.root_message(), "outer");
        let o: Option<u32> = None;
        assert_eq!(o.context("absent").unwrap_err().root_message(), "absent");
        let some: Option<u32> = Some(3);
        assert_eq!(some.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn context_chains_on_anyhow_error() {
        let base: Result<()> = Err(anyhow!("inner {}", 7));
        let e = base.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: inner 7");
    }

    #[test]
    fn macros_cover_all_arms() {
        fn fails(n: i32) -> Result<()> {
            if n == 0 {
                bail!("zero");
            }
            if n < 0 {
                bail!("negative: {n}");
            }
            Err(anyhow!(io_err()))
        }
        assert_eq!(fails(0).unwrap_err().to_string(), "zero");
        assert_eq!(fails(-2).unwrap_err().to_string(), "negative: -2");
        assert_eq!(fails(1).unwrap_err().to_string(), "missing file");
    }

    #[test]
    fn downcast_ref_recovers_typed_errors_through_context() {
        #[derive(Debug, PartialEq)]
        struct Marker(u32);
        impl fmt::Display for Marker {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "marker {}", self.0)
            }
        }
        impl std::error::Error for Marker {}

        let e: Error = Error::new(Marker(7));
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(7)));
        // Survives context wrapping (downcast walks the chain).
        let wrapped: Result<()> = Err(e);
        let wrapped = wrapped.context("outer").unwrap_err();
        assert_eq!(wrapped.downcast_ref::<Marker>(), Some(&Marker(7)));
        assert!(wrapped.is::<Marker>());
        // Absent types return None; message-only errors carry no type.
        assert!(wrapped.downcast_ref::<std::io::Error>().is_none());
        assert!(anyhow!("plain").downcast_ref::<Marker>().is_none());
        // `?`-converted std errors are downcastable too.
        let io: Error = io_err().into();
        assert!(io.is::<std::io::Error>());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("x").is_err());
    }
}
