//! Portable stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The dorafactors runtime layer compiles against the xla-rs API surface
//! (`PjRtClient`, `PjRtLoadedExecutable`, `Literal`, `HloModuleProto`).
//! The real crate links `libxla_extension`, which is not available in the
//! offline build environment, so this workspace vendors a stub that:
//!
//! * implements **host-side literals for real** (shape/dtype-tagged byte
//!   buffers, tuple decomposition) — tensor round-trip code paths work;
//! * returns a descriptive [`Error`] from every operation that would need
//!   the PJRT runtime (HLO parsing, compilation, execution).
//!
//! Callers already gate on the artifacts directory existing before running
//! executables, so the stub degrades the stack to "CPU kernels + cost
//! model only" rather than breaking the build. Linking the real backend
//! is a one-line Cargo change (point the `xla` path dep at xla-rs).

use std::fmt;

/// Error type for all fallible xla operations.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }

    fn backend_unavailable(what: &str) -> Error {
        Error(format!(
            "{what} requires the PJRT runtime, which is not linked in this \
             build (the workspace vendors the xla stub; point the `xla` \
             path dependency at xla-rs with libxla_extension to enable it)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types of the artifacts this runtime exchanges (subset of XLA's
/// primitive types; the extra variants keep dtype matches meaningful).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    U32,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::U8 => 1,
            ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::F64 => 8,
        }
    }
}

/// Rust scalar types that can view a literal's payload.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn from_ne_bytes(b: &[u8]) -> Self;
    fn to_ne_bytes_vec(v: &[Self]) -> Vec<u8>;
}

macro_rules! native {
    ($t:ty, $et:expr) => {
        impl NativeType for $t {
            const ELEMENT_TYPE: ElementType = $et;
            fn from_ne_bytes(b: &[u8]) -> Self {
                let mut buf = [0u8; std::mem::size_of::<$t>()];
                buf.copy_from_slice(b);
                <$t>::from_ne_bytes(buf)
            }
            fn to_ne_bytes_vec(v: &[Self]) -> Vec<u8> {
                let mut out = Vec::with_capacity(v.len() * std::mem::size_of::<$t>());
                for x in v {
                    out.extend_from_slice(&x.to_ne_bytes());
                }
                out
            }
        }
    };
}

native!(f32, ElementType::F32);
native!(f64, ElementType::F64);
native!(i32, ElementType::S32);
native!(i64, ElementType::S64);
native!(u32, ElementType::U32);

/// Shape of an array literal: element type + dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }
}

#[derive(Debug, Clone)]
enum LiteralData {
    Array { ty: ElementType, dims: Vec<i64>, bytes: Vec<u8> },
    Tuple(Vec<Literal>),
}

/// A host-side literal: real storage, real shape bookkeeping.
#[derive(Debug, Clone)]
pub struct Literal(LiteralData);

impl Literal {
    /// Build an array literal from a shape and raw (native-endian) bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if data.len() != elems * ty.size() {
            return Err(Error::new(format!(
                "untyped data is {} bytes, shape {dims:?} of {ty:?} wants {}",
                data.len(),
                elems * ty.size()
            )));
        }
        Ok(Literal(LiteralData::Array {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        }))
    }

    /// Rank-1 literal from a typed slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal(LiteralData::Array {
            ty: T::ELEMENT_TYPE,
            dims: vec![data.len() as i64],
            bytes: T::to_ne_bytes_vec(data),
        })
    }

    /// Reshape to new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match &self.0 {
            LiteralData::Array { ty, dims: old, bytes } => {
                let old_n: i64 = old.iter().product();
                let new_n: i64 = dims.iter().product();
                if old_n != new_n {
                    return Err(Error::new(format!(
                        "reshape {old:?} -> {dims:?} changes element count"
                    )));
                }
                Ok(Literal(LiteralData::Array {
                    ty: *ty,
                    dims: dims.to_vec(),
                    bytes: bytes.clone(),
                }))
            }
            LiteralData::Tuple(_) => Err(Error::new("cannot reshape a tuple literal")),
        }
    }

    /// Array shape accessor; errors on tuples.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.0 {
            LiteralData::Array { ty, dims, .. } => {
                Ok(ArrayShape { ty: *ty, dims: dims.clone() })
            }
            LiteralData::Tuple(_) => Err(Error::new("tuple literal has no array shape")),
        }
    }

    /// Copy the payload out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.0 {
            LiteralData::Array { ty, bytes, .. } => {
                if *ty != T::ELEMENT_TYPE {
                    return Err(Error::new(format!(
                        "literal is {ty:?}, requested {:?}",
                        T::ELEMENT_TYPE
                    )));
                }
                Ok(bytes
                    .chunks_exact(ty.size())
                    .map(T::from_ne_bytes)
                    .collect())
            }
            LiteralData::Tuple(_) => Err(Error::new("cannot read a tuple literal as a vector")),
        }
    }

    /// Build a tuple literal (used by tests and tuple-returning paths).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal(LiteralData::Tuple(parts))
    }

    /// Split a tuple literal into its parts; errors for array literals.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.0 {
            LiteralData::Tuple(parts) => Ok(std::mem::take(parts)),
            LiteralData::Array { .. } => {
                Err(Error::new("decompose_tuple on a non-tuple literal"))
            }
        }
    }
}

/// Parsed HLO module (construction requires the real backend).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::backend_unavailable("parsing HLO text"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle. The stub "CPU client" constructs successfully so
/// manifest-only workflows (info, validation) run; compile/execute error.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::backend_unavailable("compiling an executable"))
    }
}

/// Compiled executable handle (unreachable in the stub: `compile` errors).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::backend_unavailable("executing"))
    }
}

/// Device buffer handle (unreachable in the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::backend_unavailable("downloading a buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let lit = Literal::vec1(&data);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
    }

    #[test]
    fn untyped_construction_validates_length() {
        let bytes = 1.0f32.to_ne_bytes();
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &bytes).is_ok());
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &bytes).is_err()
        );
    }

    #[test]
    fn reshape_preserves_payload() {
        let lit = Literal::vec1(&[1i32, 2, 3, 4]).reshape(&[2, 2]).unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn tuple_decompose() {
        let mut t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        let mut arr = Literal::vec1(&[1.0f32]);
        assert!(arr.decompose_tuple().is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let lit = Literal::vec1(&[1.0f32]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn runtime_paths_report_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let comp = XlaComputation::from_proto(&HloModuleProto { _priv: () });
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("PJRT"));
    }
}
