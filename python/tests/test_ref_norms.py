"""The factored norm is algebraically identical to the dense reference.

Covers paper §2 (Eq. 2-5, Algorithm 1): the three norm engines agree, the
chunking is invariant, the s=0 fast path holds, and the fp32 accumulation
discipline survives bf16 inputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_wab(seed, d_out, d_in, r, dtype=jnp.float32, w_scale=0.02):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = (jax.random.normal(k1, (d_out, d_in)) * w_scale).astype(dtype)
    a = (jax.random.normal(k2, (r, d_in)) / d_in**0.5).astype(dtype)
    b = (jax.random.normal(k3, (d_out, r)) * 0.1).astype(dtype)
    return w, a, b


class TestNormAgreement:
    @pytest.mark.parametrize("d_out,d_in,r", [
        (32, 32, 4), (64, 128, 8), (128, 64, 16), (256, 256, 32),
        (128, 384, 24),
    ])
    @pytest.mark.parametrize("s", [0.25, 1.0, 2.0])
    def test_three_engines_agree_fp32(self, d_out, d_in, r, s):
        w, a, b = make_wab(0, d_out, d_in, r)
        n_peft = np.asarray(ref.peft_weight_norm(w, a, b, s))
        n_ba = np.asarray(ref.dense_ba_weight_norm(w, a, b, s))
        n_fact = np.asarray(ref.factored_weight_norm(w, a, b, s))
        np.testing.assert_allclose(n_peft, n_ba, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(n_ba, n_fact, rtol=1e-5, atol=1e-7)

    def test_chunk_invariance(self):
        """Algorithm 1 must give the same answer for any chunk size."""
        w, a, b = make_wab(1, 96, 512, 16)
        full = np.asarray(ref.factored_weight_norm(w, a, b, 0.7))
        for cs in (64, 128, 192, 256, 511, 512, 1024):
            chunked = np.asarray(
                ref.factored_weight_norm(w, a, b, 0.7, chunk_size=cs))
            np.testing.assert_allclose(full, chunked, rtol=1e-6, atol=1e-7)

    def test_scale_zero_fast_path(self):
        """s=0 skips cross/ba_sq: norm reduces to ||W||_row exactly."""
        w, a, b = make_wab(2, 64, 128, 8)
        n = np.asarray(ref.factored_weight_norm(w, a, b, 0.0))
        expect = np.linalg.norm(np.asarray(w, np.float32), axis=1)
        np.testing.assert_allclose(n, expect, rtol=1e-6)

    def test_bf16_inputs_fp32_accumulation(self):
        """bf16 weights: the factored path must cast each chunk to fp32
        BEFORE accumulation, so it tracks the fp64 truth much better than a
        pure-bf16 accumulation would."""
        w, a, b = make_wab(3, 64, 2048, 16, dtype=jnp.bfloat16, w_scale=1.0)
        got = np.asarray(
            ref.factored_weight_norm(w, a, b, 1.0, chunk_size=256))
        w64 = np.asarray(w, np.float64)
        ba64 = np.asarray(b, np.float64) @ np.asarray(a, np.float64)
        truth = np.linalg.norm(w64 + 1.0 * ba64, axis=1)
        np.testing.assert_allclose(got, truth, rtol=2e-3)

    def test_b_zero_init_gives_base_norm(self):
        """DoRA init has B=0, so the composed norm equals ||W||_row and
        g = m / ||W|| == 1 exactly — the near-unity regime."""
        w, a, _ = make_wab(4, 64, 128, 8)
        b = jnp.zeros((64, 8))
        n = np.asarray(ref.factored_weight_norm(w, a, b, 2.0))
        expect = np.linalg.norm(np.asarray(w, np.float32), axis=1)
        np.testing.assert_allclose(n, expect, rtol=1e-6)

    def test_negative_under_sqrt_clamped(self):
        """Eq. 5 clamps at 0 before sqrt; engineer tiny norms + cancellation."""
        w = jnp.zeros((4, 8))
        a = jnp.ones((2, 8)) * 1e-20
        b = jnp.ones((4, 2)) * 1e-20
        n = np.asarray(ref.factored_weight_norm(w, a, b, 1.0))
        assert np.all(np.isfinite(n)) and np.all(n >= 0)

    def test_nan_propagates(self):
        """clamp_min semantics: NaN in W must surface, not collapse to 0."""
        w = jnp.ones((4, 8)).at[1, 3].set(jnp.nan)
        a = jnp.ones((2, 8)) * 0.1
        b = jnp.ones((4, 2)) * 0.1
        n = np.asarray(ref.factored_weight_norm(w, a, b, 1.0))
        assert np.isnan(n[1]) and np.isfinite(n[0])


class TestNormTerms:
    def test_terms_match_dense_expansion(self):
        """base/cross/ba individually match their dense definitions."""
        w, a, b = make_wab(5, 48, 96, 8)
        base_sq, cross, ba_sq = ref.factored_norm_terms(w, a, b)
        wn = np.asarray(w, np.float64)
        ban = np.asarray(b, np.float64) @ np.asarray(a, np.float64)
        np.testing.assert_allclose(base_sq, (wn**2).sum(1), rtol=1e-5)
        np.testing.assert_allclose(cross, (wn * ban).sum(1), rtol=1e-4,
                                   atol=1e-8)
        np.testing.assert_allclose(ba_sq, (ban**2).sum(1), rtol=1e-4,
                                   atol=1e-10)

    @settings(max_examples=30, deadline=None)
    @given(
        d_out=st.sampled_from([16, 32, 96]),
        d_in=st.sampled_from([16, 64, 192]),
        r=st.sampled_from([2, 8, 24]),
        s=st.floats(0.01, 8.0),
        seed=st.integers(0, 2**16),
    )
    def test_property_factored_equals_dense(self, d_out, d_in, r, s, seed):
        """Hypothesis sweep: factored == dense over random shapes/scales."""
        w, a, b = make_wab(seed, d_out, d_in, r)
        dense = np.asarray(ref.dense_ba_weight_norm(w, a, b, s))
        fact = np.asarray(
            ref.factored_weight_norm(w, a, b, s, chunk_size=64))
        np.testing.assert_allclose(dense, fact, rtol=3e-5, atol=1e-6)


class TestMagnitudeDivide:
    def test_eps_floor(self):
        m = jnp.ones((4,))
        wn = jnp.array([1.0, 1e-15, 0.0, 2.0])
        g = np.asarray(ref.magnitude_divide(m, wn, 1e-12))
        assert g[0] == 1.0
        assert g[1] == g[2] == pytest.approx(1e12)
        assert g[3] == 0.5

    def test_dtype_eps_table(self):
        assert ref.dtype_eps(jnp.float32) == 1e-12
        assert ref.dtype_eps(jnp.bfloat16) == 1e-6
        assert ref.dtype_eps(jnp.float16) == 1e-6
