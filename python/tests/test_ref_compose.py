"""Compose-path oracles: stable vs naive forms, backward math, and the
paper's numerical-stability claim (§3.1, Figure 1) at the oracle level.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_acts(seed, shape, d_out, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    base = jax.random.normal(k1, (*shape, d_out)).astype(dtype)
    lora = jax.random.normal(k2, (*shape, d_out)).astype(dtype)
    g = (1.0 + 0.0015 * jax.random.normal(k3, (d_out,))).astype(jnp.float32)
    return base, lora, g


def dense_truth(base, lora, g, s):
    b = np.asarray(base, np.float64)
    l = np.asarray(lora, np.float64)
    gg = np.asarray(g, np.float64)
    return (gg - 1.0) * b + gg * s * l


class TestComposeForms:
    @pytest.mark.parametrize("s", [0.0, 0.5, 2.0])
    def test_stable_matches_fp64_truth(self, s):
        base, lora, g = make_acts(0, (4, 16), 64)
        got = np.asarray(ref.compose_stable(base, lora, g, s), np.float64)
        np.testing.assert_allclose(got, dense_truth(base, lora, g, s),
                                   rtol=1e-5, atol=1e-6)

    def test_naive_equals_stable_in_fp64(self):
        """The two forms are ALGEBRAICALLY identical — only rounding
        separates them. In fp64 they agree to machine precision.
        (Requires x64 mode: without it JAX silently truncates to fp32.)"""
        with jax.experimental.enable_x64():
            base, lora, g = make_acts(1, (8,), 32)
            b64, l64 = base.astype(jnp.float64), lora.astype(jnp.float64)
            g64 = g.astype(jnp.float64)
            naive = np.asarray(ref.compose_naive(b64, l64, g64, 1.3))
            stable = np.asarray(ref.compose_stable(b64, l64, g64, 1.3))
        np.testing.assert_allclose(naive, stable, rtol=1e-12, atol=1e-12)

    def test_bf16_collapse_zone(self):
        """Paper §3.1: when |g-1| < eps_bf16/2 ≈ 3.9e-3, the naive form
        evaluated in bf16 loses the base correction ENTIRELY, while the
        stable form (fp32 intermediates) keeps it."""
        d = 256
        base = jnp.full((16, d), 100.0, jnp.bfloat16)
        lora = jnp.zeros((16, d), jnp.bfloat16)  # isolate the base term
        g = jnp.full((d,), 1.0 + 1e-3, jnp.float32)  # inside collapse zone
        truth = dense_truth(base, lora, g, 1.0)  # = 1e-3 * 100 = 0.1

        naive = np.asarray(ref.compose_naive(base, lora, g, 1.0),
                           np.float64)
        stable = np.asarray(ref.compose_stable(base, lora, g, 1.0),
                            np.float64)
        err_naive = np.abs(naive - truth).max()
        err_stable = np.abs(stable - truth).max()
        # naive: g*base rounds to base in bf16 -> delta == 0 -> error 0.1
        assert err_naive > 0.05
        # stable keeps (g-1)*base in fp32; only the final bf16 cast rounds
        assert err_stable < 5e-4
        assert err_naive / max(err_stable, 1e-12) > 3.0  # paper: 3.0x

    def test_stable_peak_error_ratio_sweep(self):
        """Figure 1's sweep shape: peak naive error >= 3x peak stable error
        across a band of g values around unity (bf16)."""
        d = 512
        key = jax.random.PRNGKey(7)
        base = (jax.random.normal(key, (64, d)) * 10).astype(jnp.bfloat16)
        lora = jnp.zeros((64, d), jnp.bfloat16)
        worst_naive, worst_stable = 0.0, 0.0
        for delta_g in np.logspace(-5, -2, 10):
            g = jnp.full((d,), 1.0 + delta_g, jnp.float32)
            truth = dense_truth(base, lora, g, 1.0)
            scale = np.abs(truth).max() + 1e-30
            en = np.abs(np.asarray(ref.compose_naive(base, lora, g, 1.0),
                                   np.float64) - truth).max() / scale
            es = np.abs(np.asarray(ref.compose_stable(base, lora, g, 1.0),
                                   np.float64) - truth).max() / scale
            worst_naive, worst_stable = max(worst_naive, en), max(worst_stable, es)
        assert worst_naive / worst_stable > 3.0


class TestComposeBackward:
    def test_backward_matches_jax_autodiff(self):
        """The hand-derived (d_lora, d_base, d_g) equal JAX's autodiff of
        the stable compose."""
        base, lora, g = make_acts(2, (4, 8), 32)
        s = 0.8

        def f(base, lora, g):
            return jnp.sum(ref.compose_stable(base, lora, g, s) ** 2)

        gb, gl, gg = jax.grad(f, argnums=(0, 1, 2))(base, lora, g)
        d_delta = 2 * ref.compose_stable(base, lora, g, s)
        inner = s * lora + base
        d_lora, d_base, d_g = ref.compose_backward(d_delta, g, s, inner)
        np.testing.assert_allclose(np.asarray(gl), np.asarray(d_lora),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(gb), np.asarray(d_base),
                                   rtol=1e-4, atol=1e-5)
        # d_g via inner: d(delta)/dg = base + s*lora = inner
        np.testing.assert_allclose(np.asarray(gg), np.asarray(d_g),
                                   rtol=1e-3, atol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.sampled_from([1, 7, 32]),
        d_out=st.sampled_from([8, 64, 128]),
        s=st.floats(0.0, 4.0),
        seed=st.integers(0, 2**16),
    )
    def test_property_backward_linearity(self, rows, d_out, s, seed):
        """d_lora/d_base are linear in d_delta with the claimed factors."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        d_delta = jax.random.normal(k1, (rows, d_out))
        g = 1.0 + 0.01 * jax.random.normal(k2, (d_out,))
        inner = jnp.zeros_like(d_delta)
        d_lora, d_base, _ = ref.compose_backward(d_delta, g, s, inner)
        np.testing.assert_allclose(np.asarray(d_lora),
                                   np.asarray(g * s * d_delta),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(d_base),
                                   np.asarray((g - 1.0) * d_delta),
                                   rtol=1e-5, atol=1e-6)


class TestDoraDelta:
    @pytest.mark.parametrize("norm", ["peft", "dense_ba", "factored"])
    def test_module_contract_matches_direct_composition(self, norm):
        """Appendix A: y_base + delta == m ⊙ (x @ (W+sBA)^T) / ||W+sBA||."""
        k = jax.random.split(jax.random.PRNGKey(3), 5)
        d_out, d_in, r, s = 32, 48, 4, 1.5
        x = jax.random.normal(k[0], (2, 5, d_in))
        w = jax.random.normal(k[1], (d_out, d_in)) * 0.1
        a = jax.random.normal(k[2], (r, d_in)) * 0.1
        b = jax.random.normal(k[3], (d_out, r)) * 0.1
        m = jnp.abs(jax.random.normal(k[4], (d_out,))) + 0.5

        y_base, delta, g = ref.dora_delta(x, w, a, b, m, s, norm=norm)
        y = np.asarray(y_base + delta, np.float64)

        w64 = np.asarray(w, np.float64)
        comp = w64 + s * np.asarray(b, np.float64) @ np.asarray(a, np.float64)
        wn = np.linalg.norm(comp, axis=1)
        direct = (np.asarray(x, np.float64) @ comp.T) \
            * (np.asarray(m, np.float64) / wn)
        np.testing.assert_allclose(y, direct, rtol=1e-4, atol=1e-5)


class TestEmbeddingCorrection:
    """Paper §6: PEFT's embedding path omits (g-1) ⊙ base."""

    def _setup(self, seed=21, vocab=32, d=16, r=4):
        k = jax.random.split(jax.random.PRNGKey(seed), 4)
        emb = jax.random.normal(k[0], (vocab, d)) * 0.1
        a = jax.random.normal(k[1], (r, vocab)) * 0.2
        b = jax.random.normal(k[2], (d, r)) * 0.2
        # magnitude drifted away from the norm so g != 1
        m = jnp.abs(jax.random.normal(k[3], (d,))) + 0.5
        idx = jnp.array([[0, 3, 7], [1, 2, 31]])
        return idx, emb, a, b, m

    def test_corrected_matches_direct_formula(self):
        idx, emb, a, b, m = self._setup()
        s = 1.5
        base, delta = ref.embedding_dora_delta(idx, emb, a, b, m, s)
        comp = np.asarray(emb, np.float64) + s * (np.asarray(b, np.float64)
                                                  @ np.asarray(a, np.float64)).T
        wn = np.linalg.norm(comp, axis=0)
        direct = comp[np.asarray(idx)] * (np.asarray(m, np.float64) / wn)
        np.testing.assert_allclose(np.asarray(base + delta, np.float64),
                                   direct, rtol=1e-4, atol=1e-5)

    def test_legacy_omits_base_correction(self):
        idx, emb, a, b, m = self._setup()
        _, d_corr = ref.embedding_dora_delta(idx, emb, a, b, m, 1.5)
        base, d_leg = ref.embedding_dora_delta(idx, emb, a, b, m, 1.5,
                                               corrected=False)
        # They differ by exactly (g-1) * base.
        diff = np.asarray(d_corr, np.float64) - np.asarray(d_leg, np.float64)
        assert np.abs(diff).max() > 1e-3, "legacy should diverge when g != 1"

    def test_paths_agree_at_unity_g(self):
        idx, emb, a, b, _ = self._setup()
        # m == column norms => g == 1 => the omitted term vanishes.
        comp = np.asarray(emb) + 1.5 * (np.asarray(b) @ np.asarray(a)).T
        m = jnp.asarray(np.linalg.norm(comp, axis=0))
        _, d_corr = ref.embedding_dora_delta(idx, emb, a, b, m, 1.5)
        _, d_leg = ref.embedding_dora_delta(idx, emb, a, b, m, 1.5,
                                            corrected=False)
        np.testing.assert_allclose(np.asarray(d_corr), np.asarray(d_leg),
                                   rtol=1e-4, atol=1e-5)
