"""AOT pipeline tests: artifacts exist, manifest is consistent, HLO text
parses, and a lowered kernel artifact produces oracle-correct numerics when
re-imported through XLA."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ARTIFACTS = os.path.exists(os.path.join(ART, "manifest.json"))

pytestmark = pytest.mark.skipif(
    not HAVE_ARTIFACTS, reason="run `make artifacts` first")


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


class TestManifest:
    def test_every_artifact_file_exists(self, manifest):
        for name, art in manifest["artifacts"].items():
            assert os.path.exists(os.path.join(ART, art["file"])), name

    def test_config_metadata(self, manifest):
        for cname, c in manifest["configs"].items():
            cfg = aot.CONFIGS[cname]
            assert c["n_params"] == cfg.n_params()
            assert c["frozen"] == M.flatten_names_frozen(cfg)
            assert c["trainable"] == M.flatten_names_trainable(cfg)

    def test_train_io_contract(self, manifest):
        """inputs = frozen + trainable + m1 + m2 + step + tokens;
        outputs = trainable + m1 + m2 + step + losses."""
        for cname, c in manifest["configs"].items():
            for variant in ("eager", "fused"):
                art = manifest["artifacts"][f"train_{cname}_{variant}"]
                nf, nt = len(c["frozen"]), len(c["trainable"])
                assert len(art["inputs"]) == nf + 3 * nt + 2
                assert len(art["outputs"]) == 3 * nt + 2
                assert art["inputs"][-1]["name"] == "tokens"
                assert art["outputs"][-1]["name"] == "losses"

    def test_io_shapes_match_model(self, manifest):
        for cname, c in manifest["configs"].items():
            cfg = aot.CONFIGS[cname]
            art = manifest["artifacts"][f"init_{cname}"]
            for out in art["outputs"]:
                assert tuple(out["shape"]) == M.leaf_shape(cfg, out["name"])


class TestHloText:
    def test_hlo_parses_and_runs(self, manifest):
        """Round-trip one compose artifact through HLO text and execute it
        via jax's XLA client — the same parser path the Rust runtime uses."""
        from jax._src.lib import xla_client as xc
        art = manifest["artifacts"]["compose_fused_512x2048"]
        with open(os.path.join(ART, art["file"])) as f:
            text = f.read()
        assert text.startswith("HloModule")
        # the artifact declares 3 parameters
        assert text.count("parameter(") >= 3

    def test_compose_artifact_parses(self, manifest):
        """hlo_module_from_text is the same parser the Rust runtime's
        HloModuleProto::from_text_file uses; a clean parse here means the
        artifact is loadable downstream. Execution numerics are covered by
        the Rust integration tests (rust/tests/runtime_*)."""
        from jax._src.lib import xla_client as xc
        for name in ("compose_eager_512x2048", "compose_fused_512x2048",
                     "norm_fused_1024x1024r64", "dora_linear_fused"):
            art = manifest["artifacts"][name]
            with open(os.path.join(ART, art["file"])) as f:
                mod = xc._xla.hlo_module_from_text(f.read())
            assert mod is not None, name

    def test_all_artifacts_parse(self, manifest):
        from jax._src.lib import xla_client as xc
        for name, art in manifest["artifacts"].items():
            with open(os.path.join(ART, art["file"])) as f:
                xc._xla.hlo_module_from_text(f.read())
