"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes and asserts allclose against ref.py, per
the paper's operator-level fidelity envelope (§4 "Precision"): fp32 within
1e-4 max-abs, half precision within dtype-appropriate tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import compose as kc
from compile.kernels import norm as kn
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TOL = {
    jnp.dtype(jnp.float32): dict(rtol=1e-5, atol=1e-5),
    jnp.dtype(jnp.bfloat16): dict(rtol=3e-2, atol=3e-2),
    jnp.dtype(jnp.float16): dict(rtol=4e-3, atol=4e-3),
}


def make(seed, shape, d_out, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    base = jax.random.normal(k1, (*shape, d_out)).astype(dtype)
    lora = jax.random.normal(k2, (*shape, d_out)).astype(dtype)
    g = (1.0 + 0.1 * jax.random.normal(k3, (d_out,))).astype(jnp.float32)
    return base, lora, g


class TestFusedCompose:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
    @pytest.mark.parametrize("shape,d_out", [
        ((4, 32), 64), ((128,), 512), ((2, 8, 16), 128),
    ])
    def test_matches_ref(self, dtype, shape, d_out):
        base, lora, g = make(0, shape, d_out, dtype)
        got = kc.fused_compose(base, lora, g, 1.7)
        want = ref.compose_stable(base, lora, g, 1.7)
        assert got.shape == want.shape and got.dtype == want.dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[jnp.dtype(dtype)])

    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.sampled_from([1, 3, 16, 100]),
        d_out=st.sampled_from([8, 32, 96, 256]),
        s=st.floats(0.0, 4.0),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
        seed=st.integers(0, 2**16),
    )
    def test_property_sweep(self, rows, d_out, s, dtype, seed):
        """Odd row counts + non-power-of-two d_out exercise the grid-tile
        divisor logic (_tile)."""
        base, lora, g = make(seed, (rows,), d_out, dtype)
        got = kc.fused_compose(base, lora, g, s)
        want = ref.compose_stable(base, lora, g, s)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[jnp.dtype(dtype)])

    def test_near_unity_g_stability(self):
        """The kernel keeps the stable form's accuracy in the collapse zone
        (Figure 1's fused trace)."""
        d = 128
        base = jnp.full((8, d), 100.0, jnp.bfloat16)
        lora = jnp.zeros((8, d), jnp.bfloat16)
        g = jnp.full((d,), 1.0 + 1e-3, jnp.float32)
        got = np.asarray(kc.fused_compose(base, lora, g, 1.0), np.float64)
        truth = 1e-3 * 100.0
        assert np.abs(got - truth).max() < 5e-4

    def test_dual_output_inner(self):
        base, lora, g = make(1, (16,), 64, jnp.float32)
        delta, inner = kc.fused_compose_inner(base, lora, g, 0.6)
        np.testing.assert_allclose(
            np.asarray(delta), np.asarray(ref.compose_stable(base, lora, g, 0.6)),
            rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(inner), np.asarray(0.6 * lora + base),
            rtol=1e-5, atol=1e-6)


class TestFusedComposeBackward:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, dtype):
        d_delta, _, g = make(2, (4, 8), 64, dtype)
        dl, db = kc.fused_compose_bwd(d_delta, g, 1.1)
        want_dl, want_db, _ = ref.compose_backward(
            d_delta, g, 1.1, jnp.zeros_like(d_delta))
        np.testing.assert_allclose(np.asarray(dl, np.float32),
                                   np.asarray(want_dl, np.float32),
                                   **TOL[jnp.dtype(dtype)])
        np.testing.assert_allclose(np.asarray(db, np.float32),
                                   np.asarray(want_db, np.float32),
                                   **TOL[jnp.dtype(dtype)])

    def test_custom_vjp_equals_autodiff_of_eager(self):
        """Tier-1 wiring: grad through fused_compose_ad == grad through the
        eager stable compose."""
        base, lora, g = make(3, (8,), 32, jnp.float32)
        s = 0.9

        def f_fused(base, lora, g):
            return jnp.sum(jnp.sin(kc.fused_compose_ad(base, lora, g, s)))

        def f_eager(base, lora, g):
            return jnp.sum(jnp.sin(ref.compose_stable(base, lora, g, s)))

        gf = jax.grad(f_fused, argnums=(0, 1, 2))(base, lora, g)
        ge = jax.grad(f_eager, argnums=(0, 1, 2))(base, lora, g)
        for a, b in zip(gf, ge):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


class TestNormKernels:
    @pytest.mark.parametrize("d_out", [8, 64, 250, 256, 1000])
    def test_assembly_matches_ref(self, d_out):
        k = jax.random.split(jax.random.PRNGKey(4), 3)
        base_sq = jnp.abs(jax.random.normal(k[0], (d_out,))) * 10
        cross = jax.random.normal(k[1], (d_out,))
        ba_sq = jnp.abs(jax.random.normal(k[2], (d_out,)))
        for s in (0.0, 0.37, 2.0):
            got = kn.norm_assembly_kernel(base_sq, cross, ba_sq, s)
            want = ref.norm_assembly(base_sq, cross, ba_sq, s)
            # XLA may contract the eager reference's multiply-adds into
            # FMAs; the kernel's per-op rounding then differs in the last
            # bits. Well inside the paper's fp32 envelope (1e-4 max-abs).
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-5, atol=1e-6)

    def test_assembly_clamp_and_nan(self):
        base_sq = jnp.array([1.0, 0.0, jnp.nan, 4.0])
        cross = jnp.array([-10.0, 0.0, 0.0, 0.0])
        ba_sq = jnp.zeros((4,))
        got = np.asarray(kn.norm_assembly_kernel(base_sq, cross, ba_sq, 1.0))
        assert got[0] == 0.0          # clamped: 1 + 2*(-10) < 0
        assert got[1] == 0.0
        assert np.isnan(got[2])       # NaN propagates
        assert got[3] == 2.0

    @settings(max_examples=20, deadline=None)
    @given(
        d_out=st.sampled_from([16, 64, 128]),
        cs=st.sampled_from([16, 64, 128]),
        r=st.sampled_from([2, 8, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_property_chunk_kernel(self, d_out, cs, r, seed):
        k = jax.random.split(jax.random.PRNGKey(seed), 3)
        wc = jax.random.normal(k[0], (d_out, cs))
        ac = jax.random.normal(k[1], (r, cs))
        b = jax.random.normal(k[2], (d_out, r))
        base_sq, cross, gram = kn.factored_norm_chunk(wc, ac, b)
        np.testing.assert_allclose(np.asarray(base_sq),
                                   np.asarray(jnp.sum(wc * wc, axis=1)),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gram),
                                   np.asarray(ac @ ac.T), rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(cross),
            np.asarray(jnp.sum(b * (wc @ ac.T), axis=1)),
            rtol=1e-4, atol=1e-4)

    def test_full_fused_norm_path_vs_dense(self):
        """Chunk kernel + Gram assembly + assembly kernel == dense norm."""
        k = jax.random.split(jax.random.PRNGKey(5), 3)
        d_out, d_in, r, s, cs = 96, 320, 16, 1.3, 64
        w = jax.random.normal(k[0], (d_out, d_in)) * 0.05
        a = jax.random.normal(k[1], (r, d_in)) * 0.1
        b = jax.random.normal(k[2], (d_out, r)) * 0.1

        base_sq = cross = gram = None
        for st_ in range(0, d_in, cs):
            bs_c, cr_c, g_c = kn.factored_norm_chunk(
                w[:, st_:st_ + cs], a[:, st_:st_ + cs], b)
            base_sq = bs_c if base_sq is None else base_sq + bs_c
            cross = cr_c if cross is None else cross + cr_c
            gram = g_c if gram is None else gram + g_c
        ba_sq = jnp.sum((b @ gram) * b, axis=1)
        got = np.asarray(kn.norm_assembly_kernel(base_sq, cross, ba_sq, s))
        want = np.asarray(ref.dense_ba_weight_norm(w, a, b, s))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
