"""L2 model tests: shapes, variant equivalence, training dynamics, and the
flatten contract the Rust runtime depends on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=4, d_ff=128,
                    seq=32, rank=8, alpha=4.0)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(jax.random.PRNGKey(9), (4, CFG.seq + 1),
                              0, CFG.vocab)


class TestInit:
    def test_shapes_match_manifest_contract(self, params):
        frozen, trainable = params
        for name in M.flatten_names_frozen(CFG):
            assert tuple(frozen[name].shape) == M.leaf_shape(CFG, name), name
        for name in M.flatten_names_trainable(CFG):
            assert tuple(trainable[name].shape) == M.leaf_shape(CFG, name), name

    def test_dora_init_invariants(self, params):
        """B == 0 and m == ||W||_row => g == 1 at step 0."""
        frozen, trainable = params
        for p in M.PROJS:
            assert np.all(np.asarray(trainable[f"{p}_b"]) == 0.0)
            w = np.asarray(frozen[f"{p}_w"], np.float32)
            m = np.asarray(trainable[f"{p}_m"])
            np.testing.assert_allclose(m, np.linalg.norm(w, axis=2),
                                       rtol=1e-5)

    def test_param_count_matches_formula(self, params):
        frozen, trainable = params
        total = sum(int(np.prod(v.shape)) for v in frozen.values()) \
            + sum(int(np.prod(v.shape)) for v in trainable.values())
        assert total == CFG.n_params()

    def test_seed_determinism(self):
        f1, t1 = M.init_params(CFG, 123)
        f2, t2 = M.init_params(CFG, 123)
        f3, _ = M.init_params(CFG, 124)
        np.testing.assert_array_equal(np.asarray(f1["embed"]),
                                      np.asarray(f2["embed"]))
        assert np.abs(np.asarray(f1["embed"]) - np.asarray(f3["embed"])).max() > 0


class TestForward:
    def test_logits_shape(self, params, tokens):
        frozen, trainable = params
        logits = M.forward(frozen, trainable, tokens[:, :-1], CFG, "eager")
        assert logits.shape == (4, CFG.seq, CFG.vocab)
        assert logits.dtype == jnp.float32

    @pytest.mark.parametrize("variant", M.VARIANTS)
    def test_variants_agree_at_init(self, params, tokens, variant):
        """With B=0 all four configurations compute the same function."""
        frozen, trainable = params
        base = M.forward(frozen, trainable, tokens[:2, :-1], CFG, "eager")
        got = M.forward(frozen, trainable, tokens[:2, :-1], CFG, variant)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("variant", M.VARIANTS)
    def test_variants_agree_after_perturbation(self, params, tokens, variant):
        """Nonzero B (g != 1): variants still agree within fp32 envelope."""
        frozen, trainable = params
        trainable = dict(trainable)
        key = jax.random.PRNGKey(11)
        for p in M.PROJS:
            trainable[f"{p}_b"] = 0.02 * jax.random.normal(
                key, trainable[f"{p}_b"].shape)
        base = np.asarray(
            M.forward(frozen, trainable, tokens[:2, :-1], CFG, "eager"))
        got = np.asarray(
            M.forward(frozen, trainable, tokens[:2, :-1], CFG, variant))
        np.testing.assert_allclose(got, base, rtol=2e-4, atol=2e-4)

    def test_causality(self, params):
        """Future tokens must not affect current logits."""
        frozen, trainable = params
        t1 = jnp.zeros((1, CFG.seq), jnp.int32)
        t2 = t1.at[0, -1].set(5)
        l1 = M.forward(frozen, trainable, t1, CFG, "eager")
        l2 = M.forward(frozen, trainable, t2, CFG, "eager")
        np.testing.assert_allclose(np.asarray(l1[0, :-1]),
                                   np.asarray(l2[0, :-1]), rtol=1e-6)
        assert np.abs(np.asarray(l1[0, -1]) - np.asarray(l2[0, -1])).max() > 1e-3


class TestTraining:
    def _run_chunk(self, variant, params, k=3, seed=1, learnable=False):
        frozen, trainable = params
        fl, tl = M.flatten(frozen), M.flatten(trainable)
        z = [jnp.zeros_like(x) for x in tl]
        if learnable:
            # A cyclic corpus: uniform-random tokens are already at their
            # entropy floor (ln(vocab)), so the adapters would have nothing
            # to learn; a periodic pattern gives a visible loss decrease.
            seq = jnp.arange(k * 4 * (CFG.seq + 1)) % 7
            toks = seq.reshape(k, 4, CFG.seq + 1).astype(jnp.int32)
        else:
            toks = jax.random.randint(jax.random.PRNGKey(seed),
                                      (k, 4, CFG.seq + 1), 0, CFG.vocab)
        return M.train_chunk(CFG, M.OptConfig(lr=3e-3), variant, fl, tl,
                             z, z, jnp.int32(0), toks)

    def test_loss_decreases(self, params):
        out = self._run_chunk("eager", params, k=8, learnable=True)
        losses = np.asarray(out[4])
        assert losses[-1] < losses[0]

    def test_eager_fused_convergence_equivalence(self, params):
        """The paper's Table-10 property in miniature: per-step loss deltas
        between eager and fused stay tiny."""
        le = np.asarray(self._run_chunk("eager", params, k=5)[4])
        lf = np.asarray(self._run_chunk("fused", params, k=5)[4])
        assert np.abs(le - lf).max() < 1e-4

    def test_step_counter_and_state_updates(self, params):
        out = self._run_chunk("eager", params, k=3)
        tr, m1, m2, step, losses = out
        assert int(step) == 3
        assert losses.shape == (3,)
        assert any(np.abs(np.asarray(x)).max() > 0 for x in m1)

    def test_frozen_weights_not_returned(self, params):
        """train_chunk's outputs are exactly trainables+opt+step+losses —
        the frozen tree stays on the Rust side untouched."""
        out = self._run_chunk("eager", params, k=1)
        n_t = len(M.flatten_names_trainable(CFG))
        assert len(out[0]) == n_t and len(out[1]) == n_t and len(out[2]) == n_t


class TestServing:
    def test_infer_step_last_position(self, params, tokens):
        frozen, trainable = params
        fl, tl = M.flatten(frozen), M.flatten(trainable)
        logits = M.infer_step(CFG, "fused", fl, tl, tokens[:, :-1])
        assert logits.shape == (4, CFG.vocab)
        full = M.forward(frozen, trainable, tokens[:, :-1], CFG, "fused")
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, -1, :]), rtol=1e-5)

    def test_eval_loss_matches_loss_fn(self, params, tokens):
        frozen, trainable = params
        fl, tl = M.flatten(frozen), M.flatten(trainable)
        got = M.eval_loss(CFG, "eager", fl, tl, tokens)
        want = M.loss_fn(trainable, frozen, tokens, CFG, "eager")
        np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


class TestFlattenContract:
    def test_roundtrip(self, params):
        frozen, _ = params
        names = M.flatten_names(frozen)
        leaves = M.flatten(frozen)
        back = M.unflatten(names, leaves)
        assert set(back) == set(frozen)
        for k in frozen:
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(frozen[k]))

    def test_names_sorted(self):
        assert M.flatten_names_frozen(CFG) == sorted(M.flatten_names_frozen(CFG))
        assert M.flatten_names_trainable(CFG) == sorted(
            M.flatten_names_trainable(CFG))
