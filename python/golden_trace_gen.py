#!/usr/bin/env python3
"""Bit-exact replica of the native tiny/fused training path — the golden
fixture generator.

Reimplements, operation for operation and in the same floating-point
rounding order, the Rust chain behind
``Trainer::new(NativeEngine, tiny/fused) -> train_steps(52)``:

* ``util::rng`` — xoshiro256++ with splitmix64 seeding, Box-Muller
  normals (f64 ``ln``/``sqrt``/``sin``/``cos`` through the same libm).
* ``coordinator::data::MarkovCorpus`` — structure + sampling streams.
* ``models::forward::init_leaves`` — seeded leaves, magnitudes from the
  factored norm.
* ``kernels::norm::factored_norm_seq`` (f32, single chunk at the tiny
  shape) and ``norm_cpu::magnitude_divide``.
* ``kernels::generic::forward_dual_rows`` / ``backward_dmag_block`` /
  ``dmag_reduce_partials`` (the FusedCpu compose path).
* ``models::forward`` matmuls (sequential f32 accumulation, matched
  loop order), tanh residual (glibc ``tanhf`` via ctypes — the exact
  function ``f32::tanh`` calls), f64 log-sum-exp cross-entropy, and
  AdamW with the ``__powisf2`` square-and-multiply bias correction.

Every f32 op is rounded exactly where the Rust code rounds (NumPy f32
arithmetic is IEEE round-to-nearest, matching rustc's non-fast-math
codegen), and every f64 libm call goes through the same glibc the Rust
binary links. The only caveat: running this against a DIFFERENT libc
version than the one `cargo test` uses may drift by ULPs in
``tanhf``/``exp``/``log`` — regenerate the fixture with
``DORA_GOLDEN_REGEN=1 cargo test --test golden_trace`` in that case.

Why the contract is replica *tolerance* (1e-6), not bitwise
-----------------------------------------------------------
The fixture pins losses to this NumPy replica within 1e-6 rather than
bit-for-bit, deliberately. Bitwise identity would freeze the exact
floating-point summation order of every kernel into the contract, so any
legitimate performance refactor that reassociates a reduction — e.g. the
PR6 blocked GEMM cores, which keep per-element k-accumulation sequential
inside a KC=512 block but sum *block partials* for deeper contractions —
would force a fixture regeneration even though the numerics are equally
correct. The tolerance form states the real invariant: the engine
computes the same mathematical training trajectory as this executable
spec, to f32 round-off. Bitwise guarantees still exist where they are
meaningful invariants of one binary: run-to-run and worker-count
determinism are asserted bitwise in ``rust/tests/golden_trace.rs``
(same code, same order, so exact equality is the right bar there), and
1e-6 is itself a floor — the Rust loader rejects any fixture regenerated
with a looser tolerance.

The adapter-variant fixtures ride the same replica: ``rslora`` swaps the
effective scale to ``s*sqrt(r)`` (``models::forward::variant_scale``) and
``bora`` adds the frozen factored column gain
``g_col = colnorm(W)/max(colnorm(W + sBA), eps)`` on the module input
(``kernels::norm::factored_colnorm_seq``, zero-B numerator), mirrored op
for op. All variants share the Dora state at init (B = 0 makes both
gains exactly 1), so the step-1 losses agree bitwise and the traces
diverge only through training.

The greedy-decode fixture (``golden_decode_tiny.json``) rides the same
replica too: a seed-7 init with every layer's B and magnitude pushed off
init by a seeded perturbation, then greedy-argmax decode continuations
for a battery of prompts through BOTH serving paths — the composed
forward
(``models::forward::decode_logits``, full DoRA composition per step) and
the merged-weight fast path (``merge_adapter_params`` +
``merged_decode_logits``) — for all three adapter variants. Token IDs
are integers, so the fixture asserts them BITWISE: every float op on the
decode path (sequential-k matmuls, factored norms, compose order, glibc
tanhf) is mirrored exactly, and an argmax flip anywhere would change a
token.

Usage:  python3 python/golden_trace_gen.py [--check | --decode-only]
Writes: rust/tests/golden/golden_trace_tiny_fused.json
        rust/tests/golden/golden_trace_tiny_fused_rslora.json
        rust/tests/golden/golden_trace_tiny_fused_bora.json
        rust/tests/golden/golden_decode_tiny.json
"""

import ctypes
import ctypes.util
import json
import math
import os
import sys

import numpy as np

F32 = np.float32
M64 = (1 << 64) - 1

_libm = ctypes.CDLL(ctypes.util.find_library("m") or "libm.so.6")
_tanhf = _libm.tanhf
_tanhf.restype = ctypes.c_float
_tanhf.argtypes = [ctypes.c_float]


def tanhf32(arr):
    """Elementwise glibc tanhf over an f32 array (what f32::tanh calls)."""
    flat = arr.ravel()
    out = np.empty_like(flat)
    for i in range(flat.shape[0]):
        out[i] = _tanhf(ctypes.c_float(float(flat[i])))
    return out.reshape(arr.shape)


# --------------------------------------------------------------------------
# util::rng — xoshiro256++ / splitmix64 / Box-Muller
# --------------------------------------------------------------------------

F64_MIN_POSITIVE = 2.2250738585072014e-308
INV_2_53 = 1.0 / float(1 << 53)


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return state, z ^ (z >> 31)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Rng:
    def __init__(self, seed):
        sm = seed & M64
        s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s = s
        self.cached = None

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & M64, 23) + s[0]) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_f64(self):
        return float(self.next_u64() >> 11) * INV_2_53

    def below(self, n):
        threshold = ((1 << 64) - n) % n
        while True:
            x = self.next_u64()
            m = x * n
            lo = m & M64
            if lo >= n or lo >= threshold:
                return m >> 64

    def normal(self):
        if self.cached is not None:
            v = self.cached
            self.cached = None
            return v
        while True:
            u1 = self.next_f64()
            if u1 <= F64_MIN_POSITIVE:
                continue
            u2 = self.next_f64()
            r = math.sqrt(-2.0 * math.log(u1))
            theta = (2.0 * math.pi) * u2
            self.cached = r * math.sin(theta)
            return r * math.cos(theta)

    def normal_vec_f32(self, n, sigma):
        sigma = F32(sigma)
        out = np.empty(n, dtype=F32)
        for i in range(n):
            out[i] = F32(F32(self.normal()) * sigma)
        return out


# --------------------------------------------------------------------------
# coordinator::data::MarkovCorpus
# --------------------------------------------------------------------------


class MarkovCorpus:
    def __init__(self, vocab, branching, seed):
        structure = Rng((seed ^ 0x5EED5EED) & M64)
        self.succ = [
            [structure.below(vocab) for _ in range(branching)] for _ in range(vocab)
        ]
        self.rng = Rng(seed & M64)
        self.vocab = vocab

    def sequence(self, length):
        out = []
        state = self.rng.below(self.vocab)
        for _ in range(length):
            out.append(state)
            s = self.succ[state]
            state = s[self.rng.below(len(s))]
        return out

    def block(self, k, bs, length):
        out = []
        for _ in range(k * bs):
            out.extend(self.sequence(length))
        return np.asarray(out, dtype=np.int64)


# --------------------------------------------------------------------------
# Matmuls: sequential f32 accumulation in the Rust loop order. Each k-step
# does one f32 multiply and one f32 add per output element, exactly like
# the scalar loops (vectorized over the output, sequential over k).
# --------------------------------------------------------------------------


def matmul_nt(a, b):
    """C[m,n] = A[m,k] @ B[n,k]^T, acc += a[i,p]*b[j,p] sequential in p."""
    m, k = a.shape
    n = b.shape[0]
    c = np.zeros((m, n), dtype=F32)
    for p in range(k):
        c += a[:, p : p + 1] * b[:, p][None, :]
    return c


def matmul_nn(a, b):
    """C[m,n] = A[m,k] @ B[k,n], i-k-j order: += a[i,kk]*b[kk,j] seq in kk."""
    m, k = a.shape
    n = b.shape[1]
    c = np.zeros((m, n), dtype=F32)
    for kk in range(k):
        c += a[:, kk : kk + 1] * b[kk, :][None, :]
    return c


def matmul_tn(a, b):
    """C[n1,n2] = A[rows,n1]^T @ B[rows,n2], += a[i,p]*b[i,q] seq in i."""
    rows, n1 = a.shape
    c = np.zeros((n1, b.shape[1]), dtype=F32)
    for i in range(rows):
        c += a[i][:, None] * b[i][None, :]
    return c


# --------------------------------------------------------------------------
# kernels::norm::factored_norm_seq (f32 instantiation, single chunk — the
# tiny shape never splits at either the u64::MAX or 256 MB budget) and
# norm_cpu::magnitude_divide.
# --------------------------------------------------------------------------

DIVISION_EPS_F32 = F32(1e-12)


def factored_norm(w, a, b, s):
    """w [d_out, d_in], a [r, d_in], b [d_out, r]; s f32. One chunk."""
    d_out, d_in = w.shape
    r = a.shape[0]
    s64 = float(F32(s))
    # base_sq: f64 row accumulation (sequential in column), rounded once.
    acc64 = np.zeros(d_out, dtype=np.float64)
    w64 = w.astype(np.float64)
    for t in range(d_in):
        acc64 += w64[:, t] * w64[:, t]
    base_sq = F32(0.0) + acc64.astype(F32)
    # gram = A @ A^T, f32 sequential over columns.
    gram = np.zeros((r, r), dtype=F32)
    for t in range(d_in):
        gram += a[:, t][:, None] * a[:, t][None, :]
    # u_c = W @ A^T (f32 seq over columns); cross = sum(B * u_c, dim=1)
    # (f32 seq over rank).
    u_c = np.zeros((d_out, r), dtype=F32)
    for t in range(d_in):
        u_c += w[:, t : t + 1] * a[:, t][None, :]
    cacc = np.zeros(d_out, dtype=F32)
    for l in range(r):
        cacc += b[:, l] * u_c[:, l]
    cross = F32(0.0) + cacc
    # ba_sq row: bg = B @ G (seq over rank), acc = sum(bg * B) (seq).
    bg = np.zeros((d_out, r), dtype=F32)
    for t in range(r):
        bg += b[:, t : t + 1] * gram[t, :][None, :]
    ba = np.zeros(d_out, dtype=F32)
    for l in range(r):
        ba += bg[:, l] * b[:, l]
    two_s = F32(2.0 * s64)
    s2 = F32(s64 * s64)
    total = base_sq + two_s * cross + s2 * ba
    return np.sqrt(np.maximum(total, F32(0.0)))


def magnitude_divide(mag, c):
    return mag / np.maximum(c, DIVISION_EPS_F32)


def factored_colnorm(w, a, b, s):
    """kernels::norm::factored_colnorm_seq (f32, single chunk): column-wise
    ``||W + s*B@A||`` via base_sq/cross/B-Gram, axes swapped vs the row
    norm but with the same accumulation discipline."""
    d_out, d_in = w.shape
    r = a.shape[0]
    s64 = float(F32(s))
    # base_sq: f64 column accumulation (sequential in row), rounded once.
    acc64 = np.zeros(d_in, dtype=np.float64)
    w64 = w.astype(np.float64)
    for i in range(d_out):
        acc64 += w64[i] * w64[i]
    base_sq = F32(0.0) + acc64.astype(F32)
    # gram = B^T @ B [r, r]: per entry a sequential f32 dot over rows —
    # the outer-product accumulation below performs the identical
    # per-entry operation sequence.
    gram = np.zeros((r, r), dtype=F32)
    for i in range(d_out):
        gram += b[i][:, None] * b[i][None, :]
    # u_c = W^T @ B [d_in, r] (f32 seq over rows); cross[k] = sequential
    # f32 dot of u_c[k, :] against A[:, k].
    u_c = np.zeros((d_in, r), dtype=F32)
    for i in range(d_out):
        u_c += w[i][:, None] * b[i][None, :]
    cacc = np.zeros(d_in, dtype=F32)
    for l in range(r):
        cacc += u_c[:, l] * a[l]
    cross = F32(0.0) + cacc
    # ba_sq[k] = (A^T G A)_kk: ag = A[:,k]^T G (seq over t), then the
    # sequential dot against A[:,k].
    ag = np.zeros((d_in, r), dtype=F32)
    for t in range(r):
        ag += a[t][:, None] * gram[t][None, :]
    ba = np.zeros(d_in, dtype=F32)
    for l in range(r):
        ba += ag[:, l] * a[l]
    two_s = F32(2.0 * s64)
    s2 = F32(s64 * s64)
    total = base_sq + two_s * cross + s2 * ba
    return np.sqrt(np.maximum(total, F32(0.0)))


def layer_g_col(w, a, b, s):
    """models::forward::layer_g_col — BoRA's frozen column gain. The
    numerator runs the SAME factored kernel with a zero B (not s = 0), so
    at init both norms are bitwise equal and g_col = 1 exactly."""
    m_col = factored_colnorm(w, a, np.zeros_like(b), s)
    c_col = factored_colnorm(w, a, b, s)
    return magnitude_divide(m_col, c_col)


# --------------------------------------------------------------------------
# models::forward — init, forward/backward, AdamW
# --------------------------------------------------------------------------

VOCAB, D, N_LAYERS, SEQ, RANK, BS, CHUNK = 64, 32, 2, 16, 4, 4, 4
SCALE = F32(2.0)
LR = F32(1e-2)
BETA1 = F32(0.9)
BETA2 = F32(0.999)
ADAM_EPS = F32(1e-8)
WEIGHT_DECAY = F32(0.0)


def init_leaves(seed):
    rng = Rng((seed ^ 0x1A17) & M64)
    sigma = F32(F32(1.0) / F32(np.sqrt(F32(D))))
    embed = rng.normal_vec_f32(VOCAB * D, sigma).reshape(VOCAB, D)
    frozen = [embed]
    trainable = []
    for _ in range(N_LAYERS):
        w = rng.normal_vec_f32(D * D, sigma).reshape(D, D)
        a = rng.normal_vec_f32(RANK * D, sigma).reshape(RANK, D)
        b = np.zeros((D, RANK), dtype=F32)
        mag = factored_norm(w, a, b, SCALE)
        frozen.append(w)
        trainable.extend([a, b, mag])
    return frozen, trainable


def layer_g(w, a, b, mag, s=SCALE):
    c = factored_norm(w, a, b, s)
    return magnitude_divide(mag, c), c


def variant_scale(variant):
    """models::forward::variant_scale — f32 rounding order preserved."""
    if variant == "rslora":
        return F32(SCALE * F32(np.sqrt(F32(RANK))))
    return SCALE


def xent_forward_backward(logits, targets):
    """f64 log-sum-exp over f32-subtracted shifts, matching the Rust loop."""
    rows, vocab = logits.shape
    inv = F32(F32(1.0) / F32(rows))
    d = np.zeros((rows, vocab), dtype=F32)
    loss = 0.0
    for i in range(rows):
        zrow = logits[i]
        mx = F32(np.max(zrow))  # f32 max fold (no NaNs on this path)
        shift32 = zrow - mx  # f32 subtraction, then widened per element
        exps = [math.exp(float(x)) for x in shift32]
        total = 0.0
        for e in exps:  # sequential f64 accumulation in column order
            total += e
        lse = math.log(total) + float(mx)
        t = int(targets[i])
        loss += lse - float(zrow[t])
        for j in range(vocab):
            d[i, j] = F32(F32(exps[j] / total) * inv)
        d[i, t] = F32(d[i, t] - inv)
    return F32(loss / float(rows)), d


def forward_backward(frozen, trainable, tokens_block, s_eff=SCALE, bora=False):
    """One training step's loss + grads for a [bs, seq+1] token block."""
    block = tokens_block.reshape(BS, SEQ + 1)
    inputs = block[:, :SEQ].reshape(-1)
    targets = block[:, 1:].reshape(-1)
    embed = frozen[0]
    rows = inputs.shape[0]

    h = embed[inputs].copy()
    layers = []
    for l in range(N_LAYERS):
        w = frozen[1 + l]
        a, b, mag = trainable[3 * l], trainable[3 * l + 1], trainable[3 * l + 2]
        # BoRA scales the module INPUT by the frozen column gain; the
        # residual stream stays unscaled, and the trace keeps the SCALED
        # input (what the adapter gradients contract against).
        g_col = layer_g_col(w, a, b, s_eff) if bora else None
        hin = h * g_col[None, :] if g_col is not None else h
        base = matmul_nt(hin, w)
        u = matmul_nt(hin, a)
        lora = matmul_nt(u, b)
        g, c = layer_g(w, a, b, mag, s_eff)
        # forward_dual_rows: sl = s*l; t2 = g*sl; t3 = (g-1)*base;
        # delta = t3 + t2; inner = sl + base.
        sl = s_eff * lora
        t2 = g[None, :] * sl
        t3 = (g - F32(1.0))[None, :] * base
        delta = t3 + t2
        inner = sl + base
        t = tanhf32(base + delta)
        h_next = h + t
        layers.append(dict(h=hin, u=u, inner=inner, t=t, g=g, c=c, g_col=g_col))
        h = h_next
    logits = matmul_nt(h, embed)
    loss, d_logits = xent_forward_backward(logits, targets)

    # Backward.
    dh = matmul_nn(d_logits, embed)
    grads_rev = []
    for l in range(N_LAYERS - 1, -1, -1):
        tr = layers[l]
        w = frozen[1 + l]
        a, b = trainable[3 * l], trainable[3 * l + 1]
        dy = dh * (F32(1.0) - tr["t"] * tr["t"])
        # FusedCpu backward_with_dmag: 32-row blocks, f64 partials per
        # block reduced in fixed block order.
        sdd = s_eff * dy
        d_lora = tr["g"][None, :] * sdd
        d_base = (tr["g"] - F32(1.0))[None, :] * dy
        block_rows = 32
        n_blocks = (rows + block_rows - 1) // block_rows
        dg64 = np.zeros(D, dtype=np.float64)
        dy64 = dy.astype(np.float64)
        inner64 = tr["inner"].astype(np.float64)
        for blk in range(n_blocks):
            part = np.zeros(D, dtype=np.float64)
            for row in range(blk * block_rows, min((blk + 1) * block_rows, rows)):
                part += dy64[row] * inner64[row]
            dg64 += part
        dg = dg64.astype(F32)
        d_base = d_base + dy
        dmag = dg / np.maximum(tr["c"], DIVISION_EPS_F32)
        db = matmul_tn(d_lora, tr["u"])
        du = matmul_nn(d_lora, b)
        da = matmul_tn(du, tr["h"])
        dh_w = matmul_nn(d_base, w)
        dh_a = matmul_nn(du, a)
        # With BoRA the through-module input was h ⊙ g_col: both module
        # contributions pick up the frozen, detached gain.
        if tr["g_col"] is not None:
            dh = dh + (dh_w + dh_a) * tr["g_col"][None, :]
        else:
            dh = dh + (dh_w + dh_a)
        grads_rev.append([da, db, dmag])
    grads = []
    for layer_grads in reversed(grads_rev):
        grads.extend(layer_grads)
    return loss, grads


def powi_f32(a, n):
    """compiler-rt __powisf2: LSB-first square-and-multiply, f32 rounding."""
    r = F32(1.0)
    a = F32(a)
    while True:
        if n & 1:
            r = F32(r * a)
        n //= 2
        if n == 0:
            break
        a = F32(a * a)
    return r


def adamw_step(params, m1, m2, grads, t):
    bc1 = F32(F32(1.0) - powi_f32(BETA1, t))
    bc2 = F32(F32(1.0) - powi_f32(BETA2, t))
    c1 = F32(F32(1.0) - BETA1)
    c2 = F32(F32(1.0) - BETA2)
    for i in range(len(params)):
        g = grads[i]
        m1[i] = BETA1 * m1[i] + c1 * g
        m2[i] = BETA2 * m2[i] + (c2 * g) * g
        mhat = m1[i] / bc1
        vhat = m2[i] / bc2
        params[i] = params[i] - LR * (
            mhat / (np.sqrt(vhat) + ADAM_EPS) + WEIGHT_DECAY * params[i]
        )


def run_golden(seed=7, branching=3, steps=52, variant="dora"):
    frozen, trainable = init_leaves(seed)
    m1 = [np.zeros_like(t) for t in trainable]
    m2 = [np.zeros_like(t) for t in trainable]
    corpus = MarkovCorpus(VOCAB, branching, (seed ^ 0xDA7A) & M64)
    # Trainer construction draws the held-out eval block FIRST.
    _eval_tokens = corpus.block(1, BS, SEQ + 1)
    s_eff = variant_scale(variant)
    bora = variant == "bora"
    losses = []
    step = 0
    while step < steps:
        tokens = corpus.block(CHUNK, BS, SEQ + 1).reshape(CHUNK, BS * (SEQ + 1))
        for i in range(CHUNK):
            loss, grads = forward_backward(frozen, trainable, tokens[i], s_eff, bora)
            adamw_step(trainable, m1, m2, grads, step + i + 1)
            losses.append(float(loss))
        step += CHUNK
    return losses


# --------------------------------------------------------------------------
# Independent f64 shadow (loose): catches LOGIC errors in the replica —
# the bit-exact run and a straight float64 run must track each other.
# --------------------------------------------------------------------------


def run_shadow_f64(seed=7, branching=3, steps=52, variant="dora"):
    frozen, trainable = init_leaves(seed)
    frozen = [x.astype(np.float64) for x in frozen]
    trainable = [x.astype(np.float64) for x in trainable]
    m1 = [np.zeros_like(t) for t in trainable]
    m2 = [np.zeros_like(t) for t in trainable]
    corpus = MarkovCorpus(VOCAB, branching, (seed ^ 0xDA7A) & M64)
    _ = corpus.block(1, BS, SEQ + 1)
    lr, b1, b2, eps = 1e-2, 0.9, 0.999, float(F32(1e-8))
    s = float(variant_scale(variant))
    bora = variant == "bora"
    losses = []
    step = 0
    while step < steps:
        blocks = corpus.block(CHUNK, BS, SEQ + 1).reshape(CHUNK, BS, SEQ + 1)
        for i in range(CHUNK):
            inputs = blocks[i][:, :SEQ].reshape(-1)
            targets = blocks[i][:, 1:].reshape(-1)
            embed = frozen[0]
            h = embed[inputs].copy()
            layers = []
            for l in range(N_LAYERS):
                w = frozen[1 + l]
                a, b, mag = (
                    trainable[3 * l],
                    trainable[3 * l + 1],
                    trainable[3 * l + 2],
                )
                c = np.linalg.norm(w + s * (b @ a), axis=1)
                g = mag / np.maximum(c, 1e-12)
                if bora:
                    g_col = np.linalg.norm(w, axis=0) / np.maximum(
                        np.linalg.norm(w + s * (b @ a), axis=0), 1e-12
                    )
                    hin = h * g_col[None, :]
                else:
                    g_col = None
                    hin = h
                base = hin @ w.T
                u = hin @ a.T
                lora = u @ b.T
                inner = s * lora + base
                y = g[None, :] * inner
                t = np.tanh(y)
                layers.append((hin, u, inner, t, g, c, g_col))
                h = h + t
            logits = h @ embed.T
            zs = logits - logits.max(axis=1, keepdims=True)
            ez = np.exp(zs)
            p = ez / ez.sum(axis=1, keepdims=True)
            n = targets.shape[0]
            loss = float(
                np.mean(np.log(ez.sum(axis=1)) - zs[np.arange(n), targets])
            )
            d = p.copy()
            d[np.arange(n), targets] -= 1.0
            d /= n
            dh = d @ embed
            grads_rev = []
            for l in range(N_LAYERS - 1, -1, -1):
                h_in, u, inner, t, g, c, g_col = layers[l]
                w = frozen[1 + l]
                a, b = trainable[3 * l], trainable[3 * l + 1]
                dy = dh * (1.0 - t * t)
                dg = (dy * inner).sum(axis=0)
                d_inner = dy * g[None, :]
                d_lora = s * d_inner
                d_base = d_inner - dy  # (g-1)*dy == g*dy - dy
                d_base = d_base + dy  # + skip term => g*dy
                dmag = dg / np.maximum(c, 1e-12)
                db = d_lora.T @ u
                du = d_lora @ b
                da = du.T @ h_in
                dmod = d_base @ w + du @ a
                if g_col is not None:
                    dmod = dmod * g_col[None, :]
                dh = dh + dmod
                grads_rev.append([da, db, dmag])
            grads = []
            for lg in reversed(grads_rev):
                grads.extend(lg)
            tstep = step + i + 1
            for j in range(len(trainable)):
                gj = grads[j]
                m1[j] = b1 * m1[j] + (1 - b1) * gj
                m2[j] = b2 * m2[j] + (1 - b2) * gj * gj
                mhat = m1[j] / (1 - b1**tstep)
                vhat = m2[j] / (1 - b2**tstep)
                trainable[j] = trainable[j] - lr * mhat / (np.sqrt(vhat) + eps)
            losses.append(loss)
        step += CHUNK
    return losses


# --------------------------------------------------------------------------
# Greedy-decode replica: the streaming scheduler's token-sequence fixture
# (coordinator::scheduler greedy sampling over runtime decode_step /
# decode_step_merged). Bit-exact, asserted bitwise on the i32 tokens.
# --------------------------------------------------------------------------

DECODE_INIT_SEED = 7
DECODE_PERTURB_SEED = 55
DECODE_PERTURB_SCALE = 0.5
# An untrained model is a static token -> token map, so greedy decode
# reaches a short cycle within a few steps; several prompts with distinct
# last tokens pin many independent argmax decisions instead of one.
DECODE_PROMPTS = [[3, 11, 7, 2], [0], [63], [17, 29], [44, 13, 57], [31]]
DECODE_TOKENS = 6


def decode_leaves():
    """init_leaves(7) with one Rng(55) stream perturbing each layer's B
    (`*x = rng.normal() as f32 * scale`) and magnitude
    (`*x *= 1.0 + rng.normal() as f32 * scale`), in leaf order. At init
    the tied head makes every token a greedy fixed point (dot(h, embed)
    is dominated by the start embedding riding the residual stream), so
    the adapter has to be pushed hard enough that g strays well off 1
    and the token map becomes nontrivial — and off init the three
    variants are genuinely different models."""
    frozen, trainable = init_leaves(DECODE_INIT_SEED)
    vrng = Rng(DECODE_PERTURB_SEED)
    scale = F32(DECODE_PERTURB_SCALE)
    for l in range(N_LAYERS):
        b = trainable[3 * l + 1].reshape(-1)
        for i in range(b.shape[0]):
            b[i] = F32(F32(vrng.normal()) * scale)
        mag = trainable[3 * l + 2]
        for i in range(mag.shape[0]):
            mag[i] = F32(mag[i] * F32(F32(1.0) + F32(F32(vrng.normal()) * scale)))
    return frozen, trainable


def decode_composed(frozen, trainable, variant, prompt):
    """Greedy decode through models::forward::decode_logits — the full
    DoRA composition per step. One row per step: the model is row-local
    (sequential-k matmul accumulation), so a request decodes the same
    tokens bitwise at any co-resident batch size — the scheduler's
    continuous-batching determinism contract, replicated here at n=1."""
    s_eff = variant_scale(variant)
    bora = variant == "bora"
    embed = frozen[0]
    cur = prompt[-1]
    out = []
    for _ in range(DECODE_TOKENS):
        h = embed[cur : cur + 1].copy()
        for l in range(N_LAYERS):
            w = frozen[1 + l]
            a, b, mag = trainable[3 * l], trainable[3 * l + 1], trainable[3 * l + 2]
            g_col = layer_g_col(w, a, b, s_eff) if bora else None
            hin = h * g_col[None, :] if g_col is not None else h
            base = matmul_nt(hin, w)
            u = matmul_nt(hin, a)
            lora = matmul_nt(u, b)
            g, _c = layer_g(w, a, b, mag, s_eff)
            # kernels::generic::forward_rows: t1 = s*l; t2 = g*t1;
            # t3 = (g-1)*base; delta = t3 + t2.
            t1 = s_eff * lora
            t2 = g[None, :] * t1
            t3 = (g - F32(1.0))[None, :] * base
            delta = t3 + t2
            h = h + tanhf32(base + delta)
        logits = matmul_nt(h, embed)[0]
        cur = int(np.argmax(logits))  # first max index, like the scheduler
        out.append(cur)
    return out


def merge_params(frozen, trainable, variant):
    """models::forward::merge_adapter_params — f32 op order preserved:
    m[j,k] = (g[j] * (w[j,k] + s*ba[j,k])) * g_col[k], left-associated."""
    s = variant_scale(variant)
    merged = []
    for l in range(N_LAYERS):
        w = frozen[1 + l]
        a, b, mag = trainable[3 * l], trainable[3 * l + 1], trainable[3 * l + 2]
        g, _c = layer_g(w, a, b, mag, s)
        ba = matmul_nn(b, a)
        m = g[:, None] * (w + s * ba)
        if variant == "bora":
            m = m * layer_g_col(w, a, b, s)[None, :]
        merged.append(m)
    return merged


def decode_merged(frozen, trainable, variant, prompt):
    """Greedy decode through the merged fast path: one plain matmul +
    residual tanh per layer (models::forward::merged_decode_logits)."""
    embed = frozen[0]
    merged = merge_params(frozen, trainable, variant)
    cur = prompt[-1]
    out = []
    for _ in range(DECODE_TOKENS):
        h = embed[cur : cur + 1].copy()
        for m in merged:
            h = h + tanhf32(matmul_nt(h, m))
        logits = matmul_nt(h, embed)[0]
        cur = int(np.argmax(logits))
        out.append(cur)
    return out


def decode_fixture():
    frozen, trainable = decode_leaves()
    variants = {}
    for variant in ["dora", "rslora", "bora"]:
        comp = [decode_composed(frozen, trainable, variant, p) for p in DECODE_PROMPTS]
        merg = [decode_merged(frozen, trainable, variant, p) for p in DECODE_PROMPTS]
        assert all(0 <= t < VOCAB for seq in comp + merg for t in seq)
        variants[variant] = {"composed": comp, "merged": merg}
        print(f"decode {variant:7} composed {comp}")
        print(f"decode {variant:7} merged   {merg}")
    # Off init the variant math has to bite: rsLoRA (scale) and BoRA
    # (column gain) must each diverge from DoRA on at least one path.
    for other in ["rslora", "bora"]:
        assert any(
            variants["dora"][p] != variants[other][p] for p in ["composed", "merged"]
        ), f"{other} decode never diverged from dora — perturbation too small"
    return {
        "config": "tiny",
        "init_seed": DECODE_INIT_SEED,
        "n_tokens": DECODE_TOKENS,
        "perturb_scale": DECODE_PERTURB_SCALE,
        "perturb_seed": DECODE_PERTURB_SEED,
        "prompts": DECODE_PROMPTS,
        "variants": variants,
    }


def golden_dir():
    return os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "rust",
        "tests",
        "golden",
    )


def write_decode_fixture():
    out = decode_fixture()
    os.makedirs(golden_dir(), exist_ok=True)
    path = os.path.join(golden_dir(), "golden_decode_tiny.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


def main():
    if "--decode-only" in sys.argv:
        write_decode_fixture()
        return

    all_losses = {}
    for variant in ["dora", "rslora", "bora"]:
        losses = run_golden(variant=variant)
        assert len(losses) == 52
        assert all(math.isfinite(x) for x in losses)
        assert losses[0] > losses[-1], f"no learning in the {variant} run"
        # ln(64) start, entropy floor ~ln(3) target band.
        assert 3.8 < losses[0] < 4.5, losses[0]

        shadow = run_shadow_f64(variant=variant)
        worst = max(abs(a - b) for a, b in zip(losses, shadow))
        print(
            f"{variant:7} f32 first {losses[0]:.6f} last {losses[-1]:.6f} | "
            f"f64 shadow last {shadow[-1]:.6f} | max |f32 - f64| {worst:.3e}"
        )
        # Pure-precision divergence stays small over 52 tiny steps; a
        # LOGIC error in either implementation blows this up immediately.
        assert worst < 2e-2, f"{variant} replica logic divergence: {worst}"
        all_losses[variant] = losses

    # All variants share the init state (B = 0 makes every gain exactly
    # 1), so the pre-update step-1 loss is bitwise-shared; training then
    # has to diverge.
    assert all_losses["dora"][0] == all_losses["rslora"][0] == all_losses["bora"][0]
    for variant in ["rslora", "bora"]:
        gap = max(abs(a - b) for a, b in zip(all_losses["dora"], all_losses[variant]))
        print(f"{variant:7} max trace gap vs dora: {gap:.3e}")
        assert gap > 1e-3, f"{variant} never diverged from dora: {gap}"

    if "--check" in sys.argv:
        decode_fixture()  # run the decode asserts too, write nothing
        return

    out_dir = golden_dir()
    os.makedirs(out_dir, exist_ok=True)
    for variant, token, fname in [
        ("dora", "fused", "golden_trace_tiny_fused.json"),
        ("rslora", "fused-rslora", "golden_trace_tiny_fused_rslora.json"),
        ("bora", "fused-bora", "golden_trace_tiny_fused_bora.json"),
    ]:
        out = {
            "branching": 3,
            "config": "tiny",
            "losses": all_losses[variant],
            "seed": 7,
            "tolerance": 1e-6,
            "variant": token,
        }
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}")

    write_decode_fixture()


if __name__ == "__main__":
    main()
