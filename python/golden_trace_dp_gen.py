#!/usr/bin/env python3
"""Bit-exact replica of the DATA-PARALLEL native training path — the DP
golden fixture generator, and the drift check for the per-sample gradient
reduction against the legacy single-engine fixture.

The data-parallel trainer (``Trainer`` with ``--train-workers N``) computes
gradients at a FIXED shard granularity — one sample (sequence) — so the
reduced result is bitwise-identical for any worker count:

* each sample's f32 gradient is computed in isolation (forward rows are
  row-local, so batching does not change them; the backward contractions
  run over that sample's ``seq`` rows only, with the cross-entropy
  normalization ``1/total_rows`` of the EFFECTIVE batch);
* the ``GradReducer`` accumulates the per-sample f32 gradients in f64,
  in global sample order, and rounds to f32 once.

Worker count only changes WHICH engine computes a sample, never the
arithmetic, so gradients are bitwise worker-count-invariant. Relative to
the legacy full-batch path the f32 contraction chains are split at sample
boundaries and recombined in f64 — a few-ULP change this script bounds
over the golden 52-step run (must stay well inside the fixture's 1e-6).

This script:

1. re-runs the legacy replica (``golden_trace_gen.run_golden``) and the
   DP replica (accum=1) and reports the max loss drift vs the committed
   ``golden_trace_tiny_fused.json`` — both must be <= 1e-6;
2. writes ``golden_trace_tiny_fused_dp.json``: the seed-7, branching-3,
   tiny/fused trace with ``grad_accum = 2`` (effective batch 8) that the
   CI data-parallel smoke and ``tests/golden_trace.rs`` assert against.

Usage:  python3 python/golden_trace_dp_gen.py [--check]
"""

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import golden_trace_gen as gt

F32 = np.float32
M64 = (1 << 64) - 1

VOCAB, D, N_LAYERS, SEQ, RANK, BS, CHUNK = (
    gt.VOCAB,
    gt.D,
    gt.N_LAYERS,
    gt.SEQ,
    gt.RANK,
    gt.BS,
    gt.CHUNK,
)
SCALE = gt.SCALE


def xent_rows(logits, targets, inv):
    """Per-row softmax gradient with an EXPLICIT normalization constant
    (``inv = 1/total_rows`` of the effective batch), plus the f64
    per-row loss terms — the micro-batch-safe xent."""
    rows, vocab = logits.shape
    d = np.zeros((rows, vocab), dtype=F32)
    loss_terms = np.zeros(rows, dtype=np.float64)
    for i in range(rows):
        zrow = logits[i]
        mx = F32(np.max(zrow))
        shift32 = zrow - mx
        import math

        exps = [math.exp(float(x)) for x in shift32]
        total = 0.0
        for e in exps:
            total += e
        lse = math.log(total) + float(mx)
        t = int(targets[i])
        loss_terms[i] = lse - float(zrow[t])
        for j in range(vocab):
            d[i, j] = F32(F32(exps[j] / total) * inv)
        d[i, t] = F32(d[i, t] - inv)
    return loss_terms, d


def sample_grads(frozen, trainable, tokens_block, mb, total_rows):
    """One micro-batch's per-sample f32 gradients + f64 loss sums.

    Mirrors ``NativeModel::loss_and_sample_grads``: a batched forward over
    the micro-batch (row-local, so bitwise equal to any other batching),
    then an independent backward per sample over its ``seq`` rows.
    """
    block = tokens_block.reshape(mb, SEQ + 1)
    inputs = block[:, :SEQ].reshape(-1)
    targets = block[:, 1:].reshape(-1)
    embed = frozen[0]

    h = embed[inputs].copy()
    layers = []
    for l in range(N_LAYERS):
        w = frozen[1 + l]
        a, b, mag = trainable[3 * l], trainable[3 * l + 1], trainable[3 * l + 2]
        base = gt.matmul_nt(h, w)
        u = gt.matmul_nt(h, a)
        lora = gt.matmul_nt(u, b)
        g, c = gt.layer_g(w, a, b, mag)
        sl = SCALE * lora
        t2 = g[None, :] * sl
        t3 = (g - F32(1.0))[None, :] * base
        delta = t3 + t2
        inner = sl + base
        t = gt.tanhf32(base + delta)
        h_next = h + t
        layers.append(dict(h=h, u=u, inner=inner, t=t, g=g, c=c))
        h = h_next
    logits = gt.matmul_nt(h, embed)
    inv = F32(F32(1.0) / F32(total_rows))
    loss_terms, d_logits = xent_rows(logits, targets, inv)

    per_sample = []
    for smp in range(mb):
        r0, r1 = smp * SEQ, (smp + 1) * SEQ
        dh = gt.matmul_nn(d_logits[r0:r1], embed)
        grads_rev = []
        for l in range(N_LAYERS - 1, -1, -1):
            tr = layers[l]
            w = frozen[1 + l]
            a, b = trainable[3 * l], trainable[3 * l + 1]
            dy = dh * (F32(1.0) - tr["t"][r0:r1] * tr["t"][r0:r1])
            sdd = SCALE * dy
            d_lora = tr["g"][None, :] * sdd
            d_base = (tr["g"] - F32(1.0))[None, :] * dy
            # backward_with_dmag over the sample's SEQ rows: SEQ <= 32,
            # so exactly one f64 block partial, cast to f32 once.
            dg64 = np.zeros(D, dtype=np.float64)
            dy64 = dy.astype(np.float64)
            inner64 = tr["inner"][r0:r1].astype(np.float64)
            for row in range(SEQ):
                dg64 += dy64[row] * inner64[row]
            dg = dg64.astype(F32)
            d_base = d_base + dy
            dmag = dg / np.maximum(tr["c"], gt.DIVISION_EPS_F32)
            db = gt.matmul_tn(d_lora, tr["u"][r0:r1])
            du = gt.matmul_nn(d_lora, b)
            da = gt.matmul_tn(du, tr["h"][r0:r1])
            dh_w = gt.matmul_nn(d_base, w)
            dh_a = gt.matmul_nn(du, a)
            dh = dh + (dh_w + dh_a)
            grads_rev.append([da, db, dmag])
        grads = []
        for lg in reversed(grads_rev):
            grads.extend(lg)
        loss_sum = 0.0
        for i in range(r0, r1):  # sequential f64, row order
            loss_sum += loss_terms[i]
        per_sample.append((loss_sum, grads))
    return per_sample


def reduce_samples(all_samples, total_rows, n_leaves):
    """The GradReducer: f64 accumulation over per-sample f32 gradients in
    global sample order, one final f32 rounding."""
    acc = None
    loss_sum = 0.0
    for loss_s, grads in all_samples:
        loss_sum += loss_s
        if acc is None:
            acc = [g.astype(np.float64) for g in grads]
        else:
            for j in range(n_leaves):
                acc[j] += grads[j].astype(np.float64)
    reduced = [a.astype(F32) for a in acc]
    loss = F32(loss_sum / float(total_rows))
    return loss, reduced


def run_dp(seed=7, branching=3, steps=52, accum=1):
    frozen, trainable = gt.init_leaves(seed)
    m1 = [np.zeros_like(t) for t in trainable]
    m2 = [np.zeros_like(t) for t in trainable]
    corpus = gt.MarkovCorpus(VOCAB, branching, (seed ^ 0xDA7A) & M64)
    _eval_tokens = corpus.block(1, BS, SEQ + 1)
    n_leaves = len(trainable)
    total_rows = accum * BS * SEQ
    losses = []
    for step in range(steps):
        micro = corpus.block(accum, BS, SEQ + 1).reshape(accum, BS * (SEQ + 1))
        all_samples = []
        for k in range(accum):
            all_samples.extend(
                sample_grads(frozen, trainable, micro[k], BS, total_rows)
            )
        loss, grads = reduce_samples(all_samples, total_rows, n_leaves)
        gt.adamw_step(trainable, m1, m2, grads, step + 1)
        losses.append(float(loss))
    return losses


def fixture_path(name):
    return os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "rust",
        "tests",
        "golden",
        name,
    )


def main():
    with open(fixture_path("golden_trace_tiny_fused.json")) as f:
        committed = json.load(f)["losses"]

    legacy = gt.run_golden()
    drift_legacy = max(abs(a - b) for a, b in zip(legacy, committed))
    print(f"legacy replica vs committed fixture: max |d| = {drift_legacy:.3e}")
    assert drift_legacy <= 1e-6, drift_legacy

    dp1 = run_dp(accum=1)
    drift_dp = max(abs(a - b) for a, b in zip(dp1, committed))
    print(f"DP (per-sample reduce, accum=1) vs fixture: max |d| = {drift_dp:.3e}")
    assert drift_dp <= 1e-6, (
        f"per-sample reduction drifts {drift_dp:.3e} > 1e-6 over the golden run"
    )

    dp2 = run_dp(steps=16, accum=2)
    print(f"DP accum=2: first {dp2[0]:.6f}, last {dp2[-1]:.6f}")
    assert dp2[0] > dp2[-1], "no learning in the DP accum=2 run"

    if "--check" in sys.argv:
        return

    out = {
        "branching": 3,
        "config": "tiny",
        "grad_accum": 2,
        "losses": dp2,
        "seed": 7,
        "tolerance": 1e-6,
        "train_workers": "any",
        "variant": "fused",
    }
    path = fixture_path("golden_trace_tiny_fused_dp.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
