"""Pure-jnp reference oracles for every kernel in the stack.

These are the CORRECTNESS ground truth. Each function mirrors one code path
of the paper ("Scaling DoRA", §2-§4) with the paper's exact dtype
discipline:

* norms accumulate in fp32 regardless of input dtype (paper §2.2);
* the magnitude division ``g = m / max(w_norm, eps)`` is a separate stage
  shared by all norm paths (paper Eq. 6, Appendix C.4);
* the compose uses the numerically stable form
  ``(g - 1) * base + g * s * lora`` with a single canonical evaluation
  order (``s * lora`` first, then ``g * (.)``; paper §3.1).

Four weight-norm implementations reproduce the paper's four configurations:

==============  ============================================================
``peft_*``      upstream HF PEFT: identity-matrix materialization
``dense_ba_*``  direct ``B @ A`` product (still dense; the §5.3 straw-man)
``factored_*``  the paper's base/cross/Gram decomposition (Algorithm 1)
==============  ============================================================

Everything here is plain ``jax.numpy`` so it lowers to ordinary HLO and can
be compared against the Pallas kernels under ``interpret=True``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "EPS_BY_DTYPE",
    "dtype_eps",
    "peft_weight_norm",
    "dense_ba_weight_norm",
    "factored_norm_terms",
    "factored_weight_norm",
    "norm_assembly",
    "magnitude_divide",
    "compose_naive",
    "compose_stable",
    "compose_stable_inner",
    "compose_backward",
    "dora_delta",
]

# Paper Appendix B: dtype-aware epsilon. 1e-12 for fp32/fp64, 1e-6 for
# half-precision types (limits the fp16 quotient to ~1e6).
EPS_BY_DTYPE = {
    jnp.dtype(jnp.float64): 1e-12,
    jnp.dtype(jnp.float32): 1e-12,
    jnp.dtype(jnp.bfloat16): 1e-6,
    jnp.dtype(jnp.float16): 1e-6,
}


def dtype_eps(dtype) -> float:
    """The paper's dtype-dependent epsilon for the magnitude division."""
    return EPS_BY_DTYPE[jnp.dtype(dtype)]


# ---------------------------------------------------------------------------
# Weight-norm paths (paper §2). Weights are [d_out, d_in]; norms are row-wise
# (dim=1), matching PEFT / torchtune conventions.
# ---------------------------------------------------------------------------


def peft_weight_norm(w, a, b, s):
    """Upstream HF PEFT norm: materialize B(A(I)) through an identity matrix.

    Reproduces the exact op sequence of ``peft/tuners/lora/dora.py`` at
    commit 20a9829 (paper §1)::

        x_eye = torch.eye(d_in)                # [d_in, d_in]
        lora_weight = lora_B(lora_A(x_eye)).T  # [d_out, d_in]
        norm = linalg.norm(weight + scaling * lora_weight, dim=1)

    The identity matrix alone is O(d_in^2) memory; this is the baseline the
    paper beats. Computation runs in the input dtype (PEFT does not force
    fp32), then the norm itself accumulates in fp32 like
    torch.linalg.norm's internal accumulation.
    """
    d_in = w.shape[1]
    x_eye = jnp.eye(d_in, dtype=a.dtype)
    # lora_A(x_eye) = x_eye @ A.T -> [d_in, r]; lora_B(.) = . @ B.T -> [d_in, d_out]
    lora_weight = (x_eye @ a.T @ b.T).T  # [d_out, d_in]
    composed = w.astype(jnp.float32) + s * lora_weight.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(composed * composed, axis=1))


def dense_ba_weight_norm(w, a, b, s):
    """The "obvious fix" (paper §5.3): direct ``B @ A``, no identity matrix.

    Still materializes the full [d_out, d_in] product — the dominant cost —
    which is why the paper shows it captures an inconsistent fraction of the
    eager-to-fused gap.
    """
    lora_weight = b @ a  # [d_out, d_in]
    composed = w.astype(jnp.float32) + s * lora_weight.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(composed * composed, axis=1))


def factored_norm_terms(w, a, b, *, chunk_size: int | None = None):
    """Algorithm 1's three accumulators: (base_sq, cross, ba_sq), all fp32.

    ``||W + sBA||^2_row = base_sq + 2s*cross + s^2*ba_sq`` where

    * ``base_sq = ||W||^2_row`` accumulated chunk-wise along d_in,
    * ``cross_j = sum_l B_jl * U_jl`` with ``U = W A^T`` accumulated
      chunk-wise (Eq. 3),
    * ``ba_sq_j = (B G ⊙ B)_j · 1`` with Gram ``G = A A^T`` accumulated
      chunk-wise (Eq. 4).

    Every chunk of W and A is cast to fp32 *before* accumulation (paper
    §2.2: disabling autocast alone does not force fp32 for bf16 inputs).
    ``chunk_size`` mirrors the 256 MB budget knob; None means one chunk.
    """
    d_out, d_in = w.shape
    r = a.shape[0]
    if chunk_size is None or chunk_size >= d_in:
        chunk_size = d_in
    else:
        # Align to 64 elements for MXU/TensorCore tiling (paper Appendix B).
        chunk_size = max(64, (chunk_size // 64) * 64)

    base_sq = jnp.zeros((d_out,), jnp.float32)
    cross = jnp.zeros((d_out,), jnp.float32)
    gram = jnp.zeros((r, r), jnp.float32)
    bf = b.astype(jnp.float32)

    start = 0
    while start < d_in:
        stop = min(start + chunk_size, d_in)
        wc = w[:, start:stop].astype(jnp.float32)
        ac = a[:, start:stop].astype(jnp.float32)
        base_sq = base_sq + jnp.sum(wc * wc, axis=1)
        gram = gram + ac @ ac.T
        u_c = wc @ ac.T  # [d_out, r], never retained across chunks
        cross = cross + jnp.sum(bf * u_c, axis=1)
        start = stop

    ba_sq = jnp.sum((bf @ gram) * bf, axis=1)
    return base_sq, cross, ba_sq


def norm_assembly(base_sq, cross, ba_sq, s):
    """Eq. 5: ``w_norm = sqrt(max(base + 2s*cross + s^2*ba, 0))`` in fp32.

    ``2s`` and ``s^2`` are pre-computed in fp64 (paper Appendix C.3). The
    clamp preserves NaN semantics (NaN propagates, like torch.clamp_min).
    """
    two_s = jnp.float32(float(s) * 2.0)
    s2 = jnp.float32(float(s) * float(s))
    total = base_sq + two_s * cross + s2 * ba_sq
    return jnp.sqrt(jnp.maximum(total, 0.0))


def factored_weight_norm(w, a, b, s, *, chunk_size: int | None = None):
    """Full factored norm: Algorithm 1 + Eq. 5 assembly. Returns fp32.

    Scale-is-zero fast path (paper Appendix B): when s == 0, cross and
    ba_sq are skipped and U/G are never formed.
    """
    if float(s) == 0.0:
        d_out, d_in = w.shape
        base_sq = jnp.zeros((d_out,), jnp.float32)
        cs = chunk_size or d_in
        start = 0
        while start < d_in:
            stop = min(start + cs, d_in)
            wc = w[:, start:stop].astype(jnp.float32)
            base_sq = base_sq + jnp.sum(wc * wc, axis=1)
            start = stop
        return jnp.sqrt(base_sq)
    base_sq, cross, ba_sq = factored_norm_terms(w, a, b, chunk_size=chunk_size)
    return norm_assembly(base_sq, cross, ba_sq, s)


def magnitude_divide(m, w_norm, eps):
    """Eq. 6: ``g = m / max(w_norm, eps)``, always outside the kernels.

    Shared by every tier and both norm paths so precision is identical
    regardless of which engine produced ``w_norm`` (paper §2.2, §4).
    The *norm* is treated as a detached constant (DoRA paper §4.3) — callers
    wrap the norm computation in ``stop_gradient``, not this division
    (the gradient must still flow into ``m``).
    """
    return m.astype(jnp.float32) / jnp.maximum(w_norm.astype(jnp.float32), eps)


# ---------------------------------------------------------------------------
# Compose paths (paper §3.1). All operate on activations:
#   base [.., d_out] = x @ W^T (frozen-path output)
#   lora [.., d_out] = (x @ A^T) @ B^T (low-rank path; s applied in compose)
#   g    [d_out]     = m / w_norm
# and return delta so the caller applies y = base + delta.
# ---------------------------------------------------------------------------


def compose_naive(base, lora, g, s):
    """The cancellation-prone form ``g*(s*lora + base) - base`` in the input
    dtype. When g ≈ 1 and the intermediate is rounded to bf16, the base
    correction ``(g-1)*base`` vanishes entirely (paper §3.1, Figure 1)."""
    dt = base.dtype
    inner = (jnp.asarray(s, dt) * lora + base).astype(dt)
    return (g.astype(dt) * inner).astype(dt) - base


def compose_stable(base, lora, g, s):
    """The paper's stable form ``(g-1)*base + g*s*lora`` with fp32 compute.

    Canonical evaluation order: ``s * lora`` first, then ``g * (.)``
    (bf16 multiplication is non-associative; all paths share this order so
    eager-path outputs are bitwise identical). Result is cast back to the
    input dtype only at the end.
    """
    dt = base.dtype
    ct = jnp.promote_types(dt, jnp.float32)  # fp32, or fp64 for fp64 inputs
    g32 = g.astype(ct)
    b32 = base.astype(ct)
    l32 = lora.astype(ct)
    delta = (g32 - 1.0) * b32 + g32 * (jnp.asarray(s, ct) * l32)
    return delta.astype(dt)


def compose_stable_inner(base, lora, g, s):
    """Tier-1 dual-output compose: (delta, inner = s*lora + base).

    ``inner`` is the tensor the backward needs for the magnitude gradient;
    producing it in the same pass eliminates the forward-pass VRAM spike
    from sequential ops (paper §4 Tier 1).
    """
    dt = base.dtype
    g32 = g.astype(jnp.float32)
    b32 = base.astype(jnp.float32)
    sl32 = jnp.float32(s) * lora.astype(jnp.float32)
    delta = (g32 - 1.0) * b32 + g32 * sl32
    inner = sl32 + b32
    return delta.astype(dt), inner.astype(dt)


def compose_backward(d_delta, g, s, inner):
    """Backward of the stable compose w.r.t. (lora, base, g).

    ``d_lora = g * s * d_delta`` and ``d_base = (g - 1) * d_delta`` are the
    fused pair (paper §3.2). ``d_g = sum_over_rows(d_delta * inner)`` is the
    magnitude-direction gradient, computed via a separate deterministic
    reduction (never atomics; paper §3.2 bullet 2).
    """
    dt = d_delta.dtype
    g32 = g.astype(jnp.float32)
    d32 = d_delta.astype(jnp.float32)
    d_lora = (g32 * jnp.float32(s) * d32).astype(dt)
    d_base = ((g32 - 1.0) * d32).astype(dt)
    red_axes = tuple(range(d_delta.ndim - 1))
    d_g = jnp.sum(d32 * inner.astype(jnp.float32), axis=red_axes)
    return d_lora, d_base, d_g


# ---------------------------------------------------------------------------
# Whole-module reference (forward contract, paper Appendix A).
# ---------------------------------------------------------------------------


def dora_delta(x, w, a, b, m, s, *, norm="factored", chunk_size=None):
    """End-to-end DoRA delta for a linear module, per the forward contract:

        ΔY = g ⊙ (s · X A^T B^T) + (g − 1) ⊙ Y_base,   Y = Y_base + ΔY

    with the norm recomputed every call, detached, fp32-accumulated.
    ``norm`` selects 'peft' | 'dense_ba' | 'factored'.
    Returns (y_base, delta, g).
    """
    norm_fn = {
        "peft": lambda: peft_weight_norm(w, a, b, s),
        "dense_ba": lambda: dense_ba_weight_norm(w, a, b, s),
        "factored": lambda: factored_weight_norm(w, a, b, s, chunk_size=chunk_size),
    }[norm]
    w_norm = jax.lax.stop_gradient(norm_fn())
    g = magnitude_divide(m, w_norm, dtype_eps(x.dtype))
    y_base = x @ w.T
    lora = (x @ a.T) @ b.T
    delta = compose_stable(y_base, lora, g, s)
    return y_base, delta, g


# ---------------------------------------------------------------------------
# Embedding-path composition (paper §6 "Embedding formula correction").
# ---------------------------------------------------------------------------


def embedding_dora_delta(indices, emb, a, b, m, s, *, corrected=True):
    """DoRA delta for an adapted embedding layer.

    PEFT's embedding path computes only ``g ⊙ s ⊙ lora``, omitting the
    ``(g-1) ⊙ base`` term — so the magnitude re-scaling never reaches the
    frozen embedding component. ``corrected=True`` (this repo's default)
    applies the full Appendix-A contract uniformly; ``corrected=False``
    reproduces the legacy PEFT behaviour for checkpoint compatibility
    (paper: "checkpoints fine-tuned with PEFT's embedding path may require
    re-fine-tuning or a legacy composition fallback").

    Shapes (PEFT's embedding-adapter convention):
      emb [vocab, d]; a [r, vocab]; b [d, r]; m [d].
    The adapted table is ``emb + s * (B @ A).T``; norms are taken per
    embedding DIMENSION (the output axis of the lookup), i.e. over the
    [vocab, d] table's columns.
    Returns (base, delta) where the adapted lookup is base + delta.
    """
    table_delta = (b @ a).T  # [vocab, d]
    composed = emb.astype(jnp.float32) + s * table_delta.astype(jnp.float32)
    w_norm = jax.lax.stop_gradient(
        jnp.sqrt(jnp.sum(composed * composed, axis=0)))  # [d]
    g = magnitude_divide(m, w_norm, dtype_eps(emb.dtype))
    base = emb[indices]
    lora = table_delta[indices]
    if corrected:
        delta = compose_stable(base, lora, g, s)
    else:
        # Legacy PEFT: magnitude scales only the low-rank path.
        delta = (g.astype(jnp.float32)
                 * (jnp.float32(s) * lora.astype(jnp.float32))).astype(base.dtype)
    return base, delta
