"""Pallas norm kernels: assembly (paper Appendix C.3) and the factored-norm
chunk contraction (Algorithm 1, one chunk).

The paper's third Triton kernel fuses Eq. 5::

    w_norm = sqrt(max(base_sq + two_s * cross + s2 * ba_sq, 0))

with fp32 compute, store-reload barriers against FMA contraction, and an
IEEE correctly-rounded sqrt. On the XLA path the analogous guarantees are:

* fp32 compute — enforced by explicit casts here;
* no FMA reassociation — XLA does not contract ``a*b + c`` across separate
  HLO ops by default on CPU, and the interpret-mode Pallas kernel evaluates
  with numpy semantics (round-to-nearest per op), which matches the
  store-reload-barrier behaviour of the Triton kernel;
* IEEE sqrt — ``jnp.sqrt`` on fp32 is correctly rounded on CPU/XLA, i.e.
  the property the inline ``sqrt.rn.f32`` PTX restores on SM90.

The magnitude division (Eq. 6) is deliberately NOT fused — it stays in the
L2 jax model so both norm engines share one precision context (paper §4
"Magnitude division").

``factored_norm_chunk`` is the MXU-facing contraction of Algorithm 1's loop
body: given fp32-cast chunks ``W_c [d_out, cs]`` and ``A_c [r, cs]`` plus
``B [d_out, r]``, it emits the per-chunk partials
``(base_sq_c, cross_c, G_c)`` in one pallas_call; the L2 layer accumulates
them across chunks. The paper leaves this fusion to the chunked-PyTorch
path — implementing it as a kernel is the natural TPU mapping (DESIGN.md
§2) and is exercised by the 'fused' norm variant.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["norm_assembly_kernel", "factored_norm_chunk", "NORM_BLOCK"]

# Paper Appendix C.3: norm kernels are launch-latency bound; a fixed block
# of 256 is used in default mode rather than autotuning.
NORM_BLOCK = 256


def _assembly_kernel(base_ref, cross_ref, ba_ref, o_ref, *, two_s: float, s2: float):
    base_sq = base_ref[...].astype(jnp.float32)
    cross = cross_ref[...].astype(jnp.float32)
    ba_sq = ba_ref[...].astype(jnp.float32)
    # Two explicit multiply-adds; scalars were pre-computed in fp64 by the
    # caller and rounded once to fp32 (Appendix C.3).
    t1 = base_sq + jnp.float32(two_s) * cross
    total = t1 + jnp.float32(s2) * ba_sq
    # max(., 0) preserves NaN (jnp.maximum propagates NaN like clamp_min).
    o_ref[...] = jnp.sqrt(jnp.maximum(total, 0.0))


def norm_assembly_kernel(base_sq, cross, ba_sq, s, *, block=NORM_BLOCK,
                         interpret=True):
    """Fused Eq. 5 over fp32 ``[d_out]`` term vectors. Returns fp32.

    ``two_s``/``s2`` are computed in python floats (fp64) then rounded to
    fp32 exactly once, matching the kernel spec.
    """
    d_out = base_sq.shape[0]
    blk = min(block, d_out)
    while d_out % blk:
        blk -= 1
    grid = (d_out // blk,)
    spec = pl.BlockSpec((blk,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_assembly_kernel, two_s=2.0 * float(s),
                          s2=float(s) * float(s)),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((d_out,), jnp.float32),
        interpret=interpret,
    )(base_sq.astype(jnp.float32), cross.astype(jnp.float32),
      ba_sq.astype(jnp.float32))


def _chunk_kernel(w_ref, a_ref, b_ref, base_ref, cross_ref, g_ref):
    """One Algorithm-1 chunk: base_sq_c, cross_c, G_c from (W_c, A_c, B).

    The two contractions (W_c A_c^T and A_c A_c^T) are MXU work; in bf16
    inputs they run as bf16-in/fp32-acc matmuls, which is exactly the
    paper's TensorCore-aligned chunking (Appendix B). Here everything is
    pre-cast fp32 since accumulation precision is fp32 by contract.
    """
    w = w_ref[...].astype(jnp.float32)   # [d_out, cs]
    a = a_ref[...].astype(jnp.float32)   # [r, cs]
    b = b_ref[...].astype(jnp.float32)   # [d_out, r]
    base_ref[...] = jnp.sum(w * w, axis=1)
    u = jax.lax.dot_general(w, a, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [d_out, r]
    cross_ref[...] = jnp.sum(b * u, axis=1)
    g_ref[...] = jax.lax.dot_general(a, a, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)


def factored_norm_chunk(w_c, a_c, b, *, interpret=True):
    """Per-chunk factored-norm partials as a single Pallas call.

    Args:
      w_c: ``[d_out, cs]`` fp32-castable chunk of the frozen weight.
      a_c: ``[r, cs]`` matching chunk of A.
      b:   ``[d_out, r]`` full B factor.
    Returns ``(base_sq_c, cross_c, gram_c)`` — fp32 partials to be summed
    across chunks by the caller, then fed to :func:`norm_assembly_kernel`.

    Grid note: a single program instance per chunk — the chunk was already
    sized to the VMEM/working-set budget by the caller (Algorithm 1 chunking
    IS the blocking), so no further grid decomposition is needed.
    """
    d_out, cs = w_c.shape
    r = a_c.shape[0]
    return pl.pallas_call(
        _chunk_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((d_out,), jnp.float32),
            jax.ShapeDtypeStruct((d_out,), jnp.float32),
            jax.ShapeDtypeStruct((r, r), jnp.float32),
        ],
        interpret=interpret,
    )(w_c, a_c, b)
