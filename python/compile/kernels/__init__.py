"""L1 kernels: Pallas implementations + pure-jnp reference oracles.

``ref``      — ground-truth oracles (pure jnp; what pytest asserts against).
``compose``  — fused compose fwd / dual-output / bwd Pallas kernels.
``norm``     — norm-assembly and factored-norm-chunk Pallas kernels.
"""

from . import compose, norm, ref  # noqa: F401
