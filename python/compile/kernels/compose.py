"""Pallas compose kernels — the paper's Triton kernels re-targeted to TPU.

Three kernels (paper §3, Appendix C):

1. ``fused_compose``        — forward: ``delta = (g-1)*base + g*s*lora`` in
                              one pass (3 reads + 1 write per element).
2. ``fused_compose_inner``  — Tier-1 dual-output: ``(delta, inner)`` where
                              ``inner = s*lora + base`` is the saved tensor
                              the backward needs, produced in the same pass.
3. ``fused_compose_bwd``    — backward pair ``d_lora = g*s*d``,
                              ``d_base = (g-1)*d`` in one pass. ``d_g`` is a
                              separate deterministic reduction in the caller
                              (never atomics).

Hardware adaptation (DESIGN.md §2): the Triton kernels tile a CUDA grid of
thread-blocks over rows; here a 2-D Pallas grid maps ``[ROWS_TILE,
DOUT_TILE]`` blocks of ``base``/``lora`` HBM→VMEM via BlockSpec, and ``g``
rides along as a ``[DOUT_TILE]`` vector block broadcast down the rows —
the memory schedule the paper expressed with threadblocks. The stable form
and fp32 intermediate compute are preserved exactly.

All kernels run under ``interpret=True``: CPU PJRT cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO so the AOT artifacts
run anywhere (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "fused_compose",
    "fused_compose_inner",
    "fused_compose_bwd",
    "DEFAULT_ROWS_TILE",
    "DEFAULT_DOUT_TILE",
]

# Default VMEM tiles. At bf16, three input blocks + one output block of
# 256x512 are 4 * 256KiB = 1 MiB, comfortably inside a 16 MiB VMEM with
# double-buffering headroom. DOUT_TILE=512 keeps the last dim a multiple of
# the 128-lane VPU registers.
DEFAULT_ROWS_TILE = 256
DEFAULT_DOUT_TILE = 512

# AOT-for-CPU knob (EXPERIMENTS.md §Perf L1): identity blocking makes every
# compose a single block. Interpret-mode pallas then takes the direct-eval
# path below — XLA 0.5.1 compiles the multi-block lowering's 1..N-trip
# while-loops with full-array tuple state very poorly (~9x slower than the
# same math inlined). Real-TPU lowering keeps the tiled BlockSpec schedule.
def _identity_blocks() -> bool:
    return os.environ.get("PALLAS_IDENTITY_BLOCKS", "0") == "1"


def _tile(n: int, preferred: int) -> int:
    """Largest divisor of ``n`` that is <= preferred (block shape must tile
    the array exactly; shapes in this stack are powers of two)."""
    t = min(n, preferred)
    while n % t:
        t -= 1
    return t


def _compose_math(base, lora, g, s, out_dtype):
    """The kernel arithmetic, shared by the Pallas body and the
    single-block direct path: delta = (g-1)*base + g*(s*lora), fp32
    compute, stable form, canonical order (``s*lora`` first, then
    ``g*(.)`` — paper §3.1: bf16 multiplication is non-associative; one
    order everywhere)."""
    base = base.astype(jnp.float32)
    lora = lora.astype(jnp.float32)
    g = g.astype(jnp.float32)
    delta = (g - 1.0) * base + g * (jnp.float32(s) * lora)
    return delta.astype(out_dtype)


def _compose_kernel(base_ref, lora_ref, g_ref, o_ref, *, s: float):
    o_ref[...] = _compose_math(base_ref[...], lora_ref[...], g_ref[...], s,
                               o_ref.dtype)


def _compose_inner_math(base, lora, g, s, out_dtype):
    base = base.astype(jnp.float32)
    lora = lora.astype(jnp.float32)
    g = g.astype(jnp.float32)
    sl = jnp.float32(s) * lora
    delta = ((g - 1.0) * base + g * sl).astype(out_dtype)
    inner = (sl + base).astype(out_dtype)
    return delta, inner


def _compose_inner_kernel(base_ref, lora_ref, g_ref, o_ref, inner_ref, *, s: float):
    """Dual-output Tier-1 kernel: delta AND inner = s*lora + base."""
    o_ref[...], inner_ref[...] = _compose_inner_math(
        base_ref[...], lora_ref[...], g_ref[...], s, o_ref.dtype)


def _compose_bwd_math(d, g, s, out_dtype):
    d = d.astype(jnp.float32)
    g = g.astype(jnp.float32)
    d_lora = (g * (jnp.float32(s) * d)).astype(out_dtype)
    d_base = ((g - 1.0) * d).astype(out_dtype)
    return d_lora, d_base


def _compose_bwd_kernel(d_ref, g_ref, dl_ref, db_ref, *, s: float):
    """d_lora = g * s * d, d_base = (g-1) * d — one read of d, two writes.

    The Triton version reduces ROWS_PER_PROGRAM to lower register pressure
    when writing two outputs (paper §3.2); the Pallas analogue is simply a
    smaller rows tile, chosen by the caller.
    """
    dl_ref[...], db_ref[...] = _compose_bwd_math(d_ref[...], g_ref[...], s,
                                                 dl_ref.dtype)


def _grid_and_specs(shape, rows_tile, dout_tile):
    """2-D grid over (row blocks, d_out blocks) for a [rows, d_out] array.

    ``g`` is carried as a [1, d_out] array so its BlockSpec can present a
    [1, DOUT_TILE] VMEM block per grid column.
    """
    rows, d_out = shape
    rt = _tile(rows, rows_tile)
    dt_ = _tile(d_out, dout_tile)
    grid = (rows // rt, d_out // dt_)
    act_spec = pl.BlockSpec((rt, dt_), lambda i, j: (i, j))
    g_spec = pl.BlockSpec((1, dt_), lambda i, j: (0, j))
    return grid, act_spec, g_spec


def _flatten_rows(x):
    """Collapse leading dims: [..., d_out] -> [rows, d_out]."""
    d_out = x.shape[-1]
    return x.reshape(-1, d_out), x.shape


def fused_compose(base, lora, g, s, *, rows_tile=DEFAULT_ROWS_TILE,
                  dout_tile=DEFAULT_DOUT_TILE, interpret=True):
    """Single-pass DoRA compose (paper §3.1 / Appendix C.1).

    Args:
      base: ``[..., d_out]`` frozen-path activations.
      lora: ``[..., d_out]`` low-rank-path activations (un-scaled).
      g:    ``[d_out]`` post-division scale ``m / w_norm``.
      s:    python float scaling coefficient (static).
    Returns ``delta`` with ``base``'s shape and dtype.
    """
    base2, orig_shape = _flatten_rows(base)
    lora2, _ = _flatten_rows(lora)
    # g stays fp32: casting it to bf16 would round (g-1) to zero in the
    # near-unity regime — the exact collapse the stable form exists to
    # avoid (paper §3.1). base/lora stay in the input dtype.
    g2 = g.reshape(1, -1).astype(jnp.float32)
    if _identity_blocks():
        rows_tile, dout_tile = base2.shape
    grid, act_spec, g_spec = _grid_and_specs(base2.shape, rows_tile, dout_tile)
    if grid == (1, 1):
        # Single block covers the array: evaluate the kernel math directly
        # (identical ops/order; skips the interpret-mode grid machinery,
        # which XLA 0.5.1 compiles as a 1-trip while over full-array
        # tuple state — EXPERIMENTS.md §Perf L1).
        return _compose_math(base2, lora2, g2, float(s), base.dtype).reshape(orig_shape)
    out = pl.pallas_call(
        functools.partial(_compose_kernel, s=float(s)),
        grid=grid,
        in_specs=[act_spec, act_spec, g_spec],
        out_specs=act_spec,
        out_shape=jax.ShapeDtypeStruct(base2.shape, base.dtype),
        interpret=interpret,
    )(base2, lora2, g2)
    return out.reshape(orig_shape)


def fused_compose_inner(base, lora, g, s, *, rows_tile=DEFAULT_ROWS_TILE,
                        dout_tile=DEFAULT_DOUT_TILE, interpret=True):
    """Tier-1 dual-output compose: returns ``(delta, inner)`` (paper §4).

    ``inner = s*lora + base`` is what the magnitude gradient contracts
    against in the backward; emitting it here removes the sequential-op
    VRAM spike. When the magnitude is frozen, call :func:`fused_compose`
    instead and skip the allocation entirely.
    """
    base2, orig_shape = _flatten_rows(base)
    lora2, _ = _flatten_rows(lora)
    # g stays fp32: casting it to bf16 would round (g-1) to zero in the
    # near-unity regime — the exact collapse the stable form exists to
    # avoid (paper §3.1). base/lora stay in the input dtype.
    g2 = g.reshape(1, -1).astype(jnp.float32)
    if _identity_blocks():
        rows_tile, dout_tile = base2.shape
    grid, act_spec, g_spec = _grid_and_specs(base2.shape, rows_tile, dout_tile)
    if grid == (1, 1):
        delta, inner = _compose_inner_math(base2, lora2, g2, float(s), base.dtype)
        return delta.reshape(orig_shape), inner.reshape(orig_shape)
    delta, inner = pl.pallas_call(
        functools.partial(_compose_inner_kernel, s=float(s)),
        grid=grid,
        in_specs=[act_spec, act_spec, g_spec],
        out_specs=[act_spec, act_spec],
        out_shape=[
            jax.ShapeDtypeStruct(base2.shape, base.dtype),
            jax.ShapeDtypeStruct(base2.shape, base.dtype),
        ],
        interpret=interpret,
    )(base2, lora2, g2)
    return delta.reshape(orig_shape), inner.reshape(orig_shape)


def fused_compose_bwd(d_delta, g, s, *, rows_tile=DEFAULT_ROWS_TILE // 2,
                      dout_tile=DEFAULT_DOUT_TILE, interpret=True):
    """Fused backward pair (paper §3.2 / Appendix C.2).

    Returns ``(d_lora, d_base)``. The default rows tile is halved versus the
    forward: the kernel writes two outputs, doubling per-block VMEM, the
    same pressure the Triton kernel relieves via ROWS_PER_PROGRAM.

    ``d_g`` (= d_mag direction) is intentionally NOT computed here — do
    ``jnp.sum(d_delta * inner, axis=leading)`` in the caller for a
    deterministic reduction (paper: "d_mag via PyTorch reduction").
    """
    d2, orig_shape = _flatten_rows(d_delta)
    g2 = g.reshape(1, -1).astype(jnp.float32)  # fp32, see fused_compose
    if _identity_blocks():
        rows_tile, dout_tile = d2.shape
    grid, act_spec, g_spec = _grid_and_specs(d2.shape, rows_tile, dout_tile)
    if grid == (1, 1):
        d_lora, d_base = _compose_bwd_math(d2, g2, float(s), d_delta.dtype)
        return d_lora.reshape(orig_shape), d_base.reshape(orig_shape)
    d_lora, d_base = pl.pallas_call(
        functools.partial(_compose_bwd_kernel, s=float(s)),
        grid=grid,
        in_specs=[act_spec, g_spec],
        out_specs=[act_spec, act_spec],
        out_shape=[
            jax.ShapeDtypeStruct(d2.shape, d_delta.dtype),
            jax.ShapeDtypeStruct(d2.shape, d_delta.dtype),
        ],
        interpret=interpret,
    )(d2, g2)
    return d_lora.reshape(orig_shape), d_base.reshape(orig_shape)


# ---------------------------------------------------------------------------
# Autodiff wiring: Tier-1 of the paper's dispatch. The forward saves
# ``inner = s*lora + base`` via the dual-output kernel; the backward runs the
# fused pair kernel and a separate deterministic reduction for d_g
# ("d_mag via PyTorch reduction" — never atomics).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_compose_ad(base, lora, g, s):
    """Differentiable fused compose (training entry point, Tier 1).

    Identical values to :func:`fused_compose`; the custom VJP replays the
    paper's fused backward instead of differentiating through pallas_call.
    """
    return fused_compose(base, lora, g, s)


def _fused_compose_fwd(base, lora, g, s):
    delta, inner = fused_compose_inner(base, lora, g, s)
    return delta, (g, inner)


def _fused_compose_bwd_rule(s, res, d_delta):
    g, inner = res
    d_lora, d_base = fused_compose_bwd(d_delta, g, s)
    red_axes = tuple(range(d_delta.ndim - 1))
    d_g = jnp.sum(d_delta.astype(jnp.float32)
                  * inner.astype(jnp.float32), axis=red_axes)
    return d_base, d_lora, d_g.astype(g.dtype)


fused_compose_ad.defvjp(_fused_compose_fwd, _fused_compose_bwd_rule)
