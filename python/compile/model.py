"""L2: DoRA-adapted transformer in JAX, calling the L1 kernels.

This is the build-time model definition that gets AOT-lowered to HLO text
by ``aot.py`` and executed from Rust via PJRT. Python never runs on the
request path.

Architecture (a standard pre-norm decoder, the smallest shape that carries
the paper's module inventory):

* token embedding (frozen, tied LM head)
* N blocks of {RMSNorm, MHA with q/k/v/o projections, RMSNorm, SwiGLU MLP
  with gate/up/down projections}, all seven projections DoRA-adapted
* final RMSNorm

Every adapted projection follows the paper's forward contract (Appendix A):

    y_base = x @ W^T
    lora   = (x @ A^T) @ B^T                  (scale applied in compose)
    w_norm = detached, fp32, recomputed each call (factored or dense)
    g      = m / max(w_norm, eps)             (PyTorch-stage division)
    y      = y_base + compose(y_base, lora, g, s)

``variant`` selects the configuration (paper §1):
    'peft'     — identity-matrix dense norm + stable eager compose
    'dense_ba' — direct B@A dense norm + stable eager compose
    'eager'    — factored norm + stable eager compose (pure jnp)
    'fused'    — factored norm + Pallas fused compose (+ Pallas assembly)

Parameters live in stacked-per-layer pytrees so the block loop is a
``lax.scan`` (keeps the lowered HLO small at any depth). The flattening
order used for the Rust FFI boundary is defined by ``flatten_names`` and
recorded in the artifact manifest.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import compose as kcompose
from .kernels import norm as knorm
from .kernels import ref

VARIANTS = ("peft", "dense_ba", "eager", "fused")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer + DoRA hyper-parameters. ``s = alpha / sqrt(r)`` (rsLoRA,
    the scaling the paper uses throughout)."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 512
    seq: int = 128
    rank: int = 32
    alpha: float = 16.0
    dropout: float = 0.0  # p=0 keeps the fused path graph-break-free
    norm_chunk: int | None = None  # factored-norm chunk size (elements)

    @property
    def scale(self) -> float:
        return self.alpha / (self.rank ** 0.5)

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        """Total parameter count (frozen + trainable), for reporting."""
        d, f, v, r = self.d_model, self.d_ff, self.vocab, self.rank
        per_layer = 4 * d * d + 3 * d * f  # q,k,v,o + gate,up,down
        adapters = (4 * (r * d + d * r + d)
                    + 2 * (r * d + f * r + f)      # gate, up: d -> f
                    + (r * f + d * r + d))          # down: f -> d
        return v * d + self.n_layers * (per_layer + adapters + 2 * d) + d


# Names of the seven adapted projections, with (d_out, d_in) resolvers.
PROJS = ("q", "k", "v", "o", "gate", "up", "down")


def proj_dims(cfg: ModelConfig, name: str) -> tuple[int, int]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "q": (d, d), "k": (d, d), "v": (d, d), "o": (d, d),
        "gate": (f, d), "up": (f, d), "down": (d, f),
    }[name]


# ---------------------------------------------------------------------------
# Initialization (in-graph; exported as the `init` artifact so Rust obtains
# bit-reproducible parameters from a single seed).
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed):
    """DoRA init (paper §3.1): A ~ N(0, 1/d_in), B = 0, m = ||W||_row —
    hence g == 1 exactly at step 0, the near-unity regime where the stable
    compose form matters.

    Returns (frozen, trainable) pytrees with per-layer stacked leaves.
    """
    key = jax.random.PRNGKey(seed)
    n_keys = 2 + len(PROJS) * 2 * cfg.n_layers
    keys = iter(jax.random.split(key, n_keys))

    embed = jax.random.normal(next(keys), (cfg.vocab, cfg.d_model), jnp.float32) * 0.02

    frozen: dict[str, Any] = {"embed": embed, "ln_f": jnp.ones((cfg.d_model,))}
    trainable: dict[str, Any] = {}
    for name in PROJS:
        d_out, d_in = proj_dims(cfg, name)
        ws, as_, bs, ms = [], [], [], []
        for _ in range(cfg.n_layers):
            w = jax.random.normal(next(keys), (d_out, d_in), jnp.float32)
            w = w * (0.02 if name != "down" else 0.02 / (2 * cfg.n_layers) ** 0.5)
            a = jax.random.normal(next(keys), (cfg.rank, d_in), jnp.float32)
            a = a * (1.0 / d_in ** 0.5)
            b = jnp.zeros((d_out, cfg.rank), jnp.float32)
            m = jnp.sqrt(jnp.sum(w.astype(jnp.float32) ** 2, axis=1))
            ws.append(w); as_.append(a); bs.append(b); ms.append(m)
        frozen[f"{name}_w"] = jnp.stack(ws)
        trainable[f"{name}_a"] = jnp.stack(as_)
        trainable[f"{name}_b"] = jnp.stack(bs)
        trainable[f"{name}_m"] = jnp.stack(ms)
    frozen["ln_attn"] = jnp.ones((cfg.n_layers, cfg.d_model))
    frozen["ln_mlp"] = jnp.ones((cfg.n_layers, cfg.d_model))
    return frozen, trainable


def flatten_names(tree: dict[str, Any]) -> list[str]:
    """Deterministic leaf order for the Rust FFI boundary: sorted keys
    (matches jax.tree_util dict flattening order)."""
    return sorted(tree.keys())


def flatten(tree: dict[str, Any]) -> list[jax.Array]:
    return [tree[k] for k in flatten_names(tree)]


def unflatten(names: list[str], leaves) -> dict[str, Any]:
    return dict(zip(names, leaves))


# ---------------------------------------------------------------------------
# DoRA projection (the paper's module, all four variants).
# ---------------------------------------------------------------------------


def weight_norm(w, a, b, s, variant: str, chunk: int | None):
    """Detached row-wise norm of W + sBA, per variant. Returns fp32.

    'fused' uses the Pallas chunk kernel + Pallas assembly; 'eager' the
    chunked-jnp Algorithm 1; the two baselines materialize dense products.

    The norm is DETACHED (DoRA paper §4.3): inputs are stop_gradient'ed so
    no tangent ever reaches the norm computation — this both matches the
    paper's semantics and spares the Pallas kernels from needing an
    autodiff rule they'd never use.
    """
    w = jax.lax.stop_gradient(w)
    a = jax.lax.stop_gradient(a)
    b = jax.lax.stop_gradient(b)
    if variant == "peft":
        wn = ref.peft_weight_norm(w, a, b, s)
    elif variant == "dense_ba":
        wn = ref.dense_ba_weight_norm(w, a, b, s)
    elif variant == "eager":
        wn = ref.factored_weight_norm(w, a, b, s, chunk_size=chunk)
    elif variant == "fused":
        d_in = w.shape[1]
        cs = min(chunk or d_in, d_in)
        base_sq = cross = gram = None
        start = 0
        while start < d_in:
            stop = min(start + cs, d_in)
            bs_c, cr_c, g_c = knorm.factored_norm_chunk(
                w[:, start:stop].astype(jnp.float32),
                a[:, start:stop].astype(jnp.float32),
                b.astype(jnp.float32))
            base_sq = bs_c if base_sq is None else base_sq + bs_c
            cross = cr_c if cross is None else cross + cr_c
            gram = g_c if gram is None else gram + g_c
            start = stop
        # ba_sq via the accumulated Gram (Eq. 4) then fused assembly (Eq. 5).
        bf = b.astype(jnp.float32)
        ba_sq = jnp.sum((bf @ gram) * bf, axis=1)
        wn = knorm.norm_assembly_kernel(base_sq, cross, ba_sq, s)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return jax.lax.stop_gradient(wn)


def dora_proj(x, w, a, b, m, cfg: ModelConfig, variant: str):
    """One adapted projection y = base + compose(base, lora, g, s)."""
    s = cfg.scale
    wn = weight_norm(w, a, b, s, variant, cfg.norm_chunk)
    g = ref.magnitude_divide(m, wn, ref.dtype_eps(x.dtype))
    y_base = x @ w.T
    lora = (x @ a.T) @ b.T
    if variant == "fused":
        # Tier-1 path: custom VJP replays the fused backward kernel.
        delta = kcompose.fused_compose_ad(y_base, lora, g, s)
    else:
        delta = ref.compose_stable(y_base, lora, g, s)
    return y_base + delta


# ---------------------------------------------------------------------------
# Transformer.
# ---------------------------------------------------------------------------


def rms_norm(x, gamma, eps=1e-6):
    var = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def rope(x, positions):
    """Rotary position embedding over the head dim (standard theta=10000)."""
    *_, h = x.shape
    half = h // 2
    freq = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]  # [seq, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def attention(x, layer_p, cfg: ModelConfig, variant: str):
    bs, seq, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = dora_proj(x, layer_p["q_w"], layer_p["q_a"], layer_p["q_b"], layer_p["q_m"], cfg, variant)
    k = dora_proj(x, layer_p["k_w"], layer_p["k_a"], layer_p["k_b"], layer_p["k_m"], cfg, variant)
    v = dora_proj(x, layer_p["v_w"], layer_p["v_a"], layer_p["v_b"], layer_p["v_m"], cfg, variant)

    pos = jnp.arange(seq)
    q = rope(q.reshape(bs, seq, h, hd).transpose(0, 2, 1, 3), pos)
    k = rope(k.reshape(bs, seq, h, hd).transpose(0, 2, 1, 3), pos)
    v = v.reshape(bs, seq, h, hd).transpose(0, 2, 1, 3)

    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(bs, seq, d)
    return dora_proj(ctx, layer_p["o_w"], layer_p["o_a"], layer_p["o_b"], layer_p["o_m"], cfg, variant)


def mlp(x, layer_p, cfg: ModelConfig, variant: str):
    gate = dora_proj(x, layer_p["gate_w"], layer_p["gate_a"], layer_p["gate_b"], layer_p["gate_m"], cfg, variant)
    up = dora_proj(x, layer_p["up_w"], layer_p["up_a"], layer_p["up_b"], layer_p["up_m"], cfg, variant)
    act = jax.nn.silu(gate) * up
    return dora_proj(act, layer_p["down_w"], layer_p["down_a"], layer_p["down_b"], layer_p["down_m"], cfg, variant)


def block(x, layer_p, cfg: ModelConfig, variant: str):
    x = x + attention(rms_norm(x, layer_p["ln_attn"]), layer_p, cfg, variant)
    x = x + mlp(rms_norm(x, layer_p["ln_mlp"]), layer_p, cfg, variant)
    return x


def forward(frozen, trainable, tokens, cfg: ModelConfig, variant: str):
    """tokens [bs, seq] int32 -> logits [bs, seq, vocab] (fp32)."""
    x = frozen["embed"][tokens]

    # Per-layer stacked params -> scan. Layer-indexed leaves get sliced by
    # the scan carry; shared leaves (embed, ln_f) stay outside.
    layer_keys = ([f"{p}_{t}" for p in PROJS for t in ("w",)]
                  + ["ln_attn", "ln_mlp"])
    train_keys = [f"{p}_{t}" for p in PROJS for t in ("a", "b", "m")]
    stacked = {k: frozen[k] for k in layer_keys}
    stacked.update({k: trainable[k] for k in train_keys})

    def body(h, layer_p):
        return block(h, layer_p, cfg, variant), None

    x, _ = jax.lax.scan(body, x, stacked)
    x = rms_norm(x, frozen["ln_f"])
    return (x @ frozen["embed"].T).astype(jnp.float32)


def loss_fn(trainable, frozen, tokens, cfg: ModelConfig, variant: str):
    """Next-token cross-entropy. tokens [bs, seq+1]."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(frozen, trainable, inp, cfg, variant)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Optimizer (AdamW, in-graph) and the train/infer entry points that aot.py
# lowers. All entry points take/return FLAT LISTS in flatten_names order so
# the Rust side can feed Literals without a pytree library.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0  # adapters conventionally skip decay


def adamw_update(p, g, m1, m2, step, oc: OptConfig):
    m1 = oc.beta1 * m1 + (1 - oc.beta1) * g
    m2 = oc.beta2 * m2 + (1 - oc.beta2) * g * g
    t = step.astype(jnp.float32)
    mhat = m1 / (1 - oc.beta1 ** t)
    vhat = m2 / (1 - oc.beta2 ** t)
    p = p - oc.lr * (mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * p)
    return p, m1, m2


def train_chunk(cfg: ModelConfig, oc: OptConfig, variant: str,
                frozen_leaves, train_leaves, m1_leaves, m2_leaves, step,
                tokens):
    """Run ``k`` optimizer steps in-graph (k = tokens.shape[0]).

    tokens: [k, bs, seq+1] int32. Returns (new trainables, new m1, new m2,
    new step, losses[k]). Lowered once per (cfg, variant); Rust calls it
    repeatedly, so the host round-trip amortizes over k steps.
    """
    fnames = flatten_names_frozen(cfg)
    tnames = flatten_names_trainable(cfg)
    frozen = unflatten(fnames, frozen_leaves)

    def body(carry, batch):
        tr, m1, m2, step = carry
        trainable = unflatten(tnames, tr)
        loss, grads = jax.value_and_grad(loss_fn)(
            trainable, frozen, batch, cfg, variant)
        gl = flatten(grads)
        new_tr, new_m1, new_m2 = [], [], []
        step = step + 1
        for p, g, a, b in zip(tr, gl, m1, m2):
            np_, na, nb = adamw_update(p, g, a, b, step, oc)
            new_tr.append(np_); new_m1.append(na); new_m2.append(nb)
        return (new_tr, new_m1, new_m2, step), loss

    (tr, m1, m2, step), losses = jax.lax.scan(
        body, (list(train_leaves), list(m1_leaves), list(m2_leaves), step),
        tokens)
    return tr, m1, m2, step, losses


def infer_step(cfg: ModelConfig, variant: str, frozen_leaves, train_leaves,
               tokens):
    """tokens [bs, seq] -> logits of the LAST position [bs, vocab] (keeps
    the serving artifact output small)."""
    frozen = unflatten(flatten_names_frozen(cfg), frozen_leaves)
    trainable = unflatten(flatten_names_trainable(cfg), train_leaves)
    logits = forward(frozen, trainable, tokens, cfg, variant)
    return logits[:, -1, :]


def eval_loss(cfg: ModelConfig, variant: str, frozen_leaves, train_leaves,
              tokens):
    """tokens [bs, seq+1] -> scalar mean loss (for eval curves)."""
    frozen = unflatten(flatten_names_frozen(cfg), frozen_leaves)
    trainable = unflatten(flatten_names_trainable(cfg), train_leaves)
    return loss_fn(trainable, frozen, tokens, cfg, variant)


def flatten_names_frozen(cfg: ModelConfig) -> list[str]:
    names = ["embed", "ln_f", "ln_attn", "ln_mlp"] + [f"{p}_w" for p in PROJS]
    return sorted(names)


def flatten_names_trainable(cfg: ModelConfig) -> list[str]:
    return sorted(f"{p}_{t}" for p in PROJS for t in ("a", "b", "m"))


def leaf_shape(cfg: ModelConfig, name: str) -> tuple[int, ...]:
    """Shape of a flattened leaf by name (manifest generation)."""
    L, r = cfg.n_layers, cfg.rank
    if name == "embed":
        return (cfg.vocab, cfg.d_model)
    if name == "ln_f":
        return (cfg.d_model,)
    if name in ("ln_attn", "ln_mlp"):
        return (L, cfg.d_model)
    proj, kind = name.rsplit("_", 1)
    d_out, d_in = proj_dims(cfg, proj)
    return {
        "w": (L, d_out, d_in),
        "a": (L, r, d_in),
        "b": (L, d_out, r),
        "m": (L, d_out),
    }[kind]


# ---------------------------------------------------------------------------
# Standalone module-level entry points (microbenchmark / runtime-test
# artifacts): one DoRA linear, one compose, one norm.
# ---------------------------------------------------------------------------


def dora_linear(cfg: ModelConfig, variant: str, x, w, a, b, m):
    """Single adapted projection, the Appendix-A contract in isolation."""
    return dora_proj(x, w, a, b, m, cfg, variant)


def compose_only(variant: str, s: float, base, lora, g):
    if variant == "fused":
        return kcompose.fused_compose(base, lora, g, s)
    return ref.compose_stable(base, lora, g, s)


def norm_only(variant: str, s: float, chunk: int | None, w, a, b):
    cfg = ModelConfig(norm_chunk=chunk)
    return weight_norm(w, a, b, s, variant, chunk)
