"""AOT compiler: lower L2/L1 JAX programs to HLO *text* artifacts.

``python -m compile.aot --out ../artifacts`` writes one ``.hlo.txt`` per
entry point plus ``manifest.json`` describing every artifact's I/O
signature, so the Rust runtime can feed PJRT literals without a pytree
library.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# All AOT artifacts target CPU-PJRT execution: identity blocking (see
# kernels/compose.py — the single-block direct path) avoids XLA 0.5.1's
# poor compilation of interpret-mode grid loops. Set before any lowering.
os.environ.setdefault("PALLAS_IDENTITY_BLOCKS", "1")

# ---------------------------------------------------------------------------
# Configurations exported for the Rust side.
#
# tiny  — runtime integration tests (sub-second compiles)
# small — convergence study (Table 10 / Fig 12) + serving example
# e2e   — the ~100M-parameter end-to-end training driver
# ---------------------------------------------------------------------------

CONFIGS: dict[str, M.ModelConfig] = {
    "tiny": M.ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=4,
                          d_ff=128, seq=32, rank=8, alpha=4.0),
    "small": M.ModelConfig(vocab=512, d_model=256, n_layers=4, n_heads=8,
                           d_ff=512, seq=128, rank=32, alpha=16.0),
    "e2e": M.ModelConfig(vocab=16384, d_model=768, n_layers=12, n_heads=12,
                         d_ff=3072, seq=256, rank=64, alpha=32.0),
}

# (batch size, in-graph steps per chunk) per config.
TRAIN_SHAPES = {"tiny": (4, 2), "small": (4, 10), "e2e": (2, 5)}

# Which configs get training/serving artifacts per variant.
TRAIN_VARIANTS = ("eager", "fused")

# Standalone compose-kernel shapes (rows = batch*seq), mirroring the
# paper's microbenchmark sweep classes (§5.4).
COMPOSE_SHAPES = [(512, 2048), (2048, 4096), (4096, 8192)]

# Standalone norm shapes (d_out, d_in, rank) — Table 7 classes scaled to
# CPU-friendly sizes plus one paper-exact shape.
NORM_SHAPES = [(1024, 1024, 64), (2048, 2048, 384), (4096, 4096, 512)]

OPT = M.OptConfig()


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def leaf_specs(cfg: M.ModelConfig, names):
    return [spec(M.leaf_shape(cfg, n)) for n in names]


def io_entry(name, shape, dtype, role):
    return {"name": name, "shape": list(shape), "dtype": dtype, "role": role}


@dataclasses.dataclass
class Artifact:
    name: str
    fn: object
    in_specs: list
    inputs: list  # manifest io entries
    outputs: list
    meta: dict


def build_artifacts(only: str | None, skip_e2e: bool) -> list[Artifact]:
    arts: list[Artifact] = []

    def add(name, fn, in_specs, inputs, outputs, **meta):
        if only and only not in name:
            return
        arts.append(Artifact(name, fn, in_specs, inputs, outputs, meta))

    # -- standalone kernels ------------------------------------------------
    for rows, d_out in COMPOSE_SHAPES:
        for variant in ("eager", "fused"):
            s = 2.0
            add(
                f"compose_{variant}_{rows}x{d_out}",
                lambda base, lora, g, v=variant, s_=s: M.compose_only(v, s_, base, lora, g),
                [spec((rows, d_out)), spec((rows, d_out)), spec((d_out,))],
                [io_entry("base", (rows, d_out), "f32", "data"),
                 io_entry("lora", (rows, d_out), "f32", "data"),
                 io_entry("g", (d_out,), "f32", "data")],
                [io_entry("delta", (rows, d_out), "f32", "out")],
                kind="compose", variant=variant, rows=rows, d_out=d_out, scale=s,
            )

    for d_out, d_in, r in NORM_SHAPES:
        for variant in ("dense_ba", "eager", "fused"):
            s = 0.5
            chunk = min(d_in, 1024)
            add(
                f"norm_{variant}_{d_out}x{d_in}r{r}",
                lambda w, a, b, v=variant, s_=s, c=chunk: M.norm_only(v, s_, c, w, a, b),
                [spec((d_out, d_in)), spec((r, d_in)), spec((d_out, r))],
                [io_entry("w", (d_out, d_in), "f32", "data"),
                 io_entry("a", (r, d_in), "f32", "data"),
                 io_entry("b", (d_out, r), "f32", "data")],
                [io_entry("w_norm", (d_out,), "f32", "out")],
                kind="norm", variant=variant, d_out=d_out, d_in=d_in, rank=r,
                scale=s, chunk=chunk,
            )

    # -- single DoRA linear (quickstart + runtime cross-check) -------------
    lin_cfg = M.ModelConfig(d_model=256, d_ff=512, rank=32, alpha=16.0,
                            norm_chunk=256)
    bs, sq, d = 2, 64, 256
    for variant in M.VARIANTS:
        add(
            f"dora_linear_{variant}",
            lambda x, w, a, b, m, v=variant: M.dora_linear(lin_cfg, v, x, w, a, b, m),
            [spec((bs, sq, d)), spec((d, d)), spec((lin_cfg.rank, d)),
             spec((d, lin_cfg.rank)), spec((d,))],
            [io_entry("x", (bs, sq, d), "f32", "data"),
             io_entry("w", (d, d), "f32", "frozen"),
             io_entry("a", (lin_cfg.rank, d), "f32", "trainable"),
             io_entry("b", (d, lin_cfg.rank), "f32", "trainable"),
             io_entry("m", (d,), "f32", "trainable")],
            [io_entry("y", (bs, sq, d), "f32", "out")],
            kind="dora_linear", variant=variant, scale=lin_cfg.scale,
            d_model=d, rank=lin_cfg.rank,
        )

    # -- per-config model programs ------------------------------------------
    for cname, cfg in CONFIGS.items():
        if skip_e2e and cname == "e2e":
            continue
        fnames = M.flatten_names_frozen(cfg)
        tnames = M.flatten_names_trainable(cfg)
        bs, k = TRAIN_SHAPES[cname]

        frozen_io = [io_entry(n, M.leaf_shape(cfg, n), "f32", "frozen") for n in fnames]
        train_io = [io_entry(n, M.leaf_shape(cfg, n), "f32", "trainable") for n in tnames]
        m1_io = [io_entry(f"m1.{n}", M.leaf_shape(cfg, n), "f32", "opt") for n in tnames]
        m2_io = [io_entry(f"m2.{n}", M.leaf_shape(cfg, n), "f32", "opt") for n in tnames]

        # init: seed -> frozen..., trainable..., (opt state is zeros; Rust
        # materializes those locally to avoid doubling the artifact I/O).
        def init_fn(seed, cfg=cfg):
            frozen, trainable = M.init_params(cfg, seed)
            return tuple(M.flatten(frozen)) + tuple(M.flatten(trainable))

        add(
            f"init_{cname}",
            init_fn,
            [spec((), jnp.int32)],
            [io_entry("seed", (), "s32", "data")],
            frozen_io + train_io,
            kind="init", config=cname,
        )

        for variant in TRAIN_VARIANTS:
            def train_fn(*leaves, cfg=cfg, v=variant, nf=len(fnames), nt=len(tnames)):
                fl = leaves[:nf]
                tl = leaves[nf:nf + nt]
                m1 = leaves[nf + nt:nf + 2 * nt]
                m2 = leaves[nf + 2 * nt:nf + 3 * nt]
                step = leaves[nf + 3 * nt]
                tokens = leaves[nf + 3 * nt + 1]
                tr, m1_, m2_, step_, losses = M.train_chunk(
                    cfg, OPT, v, fl, tl, m1, m2, step, tokens)
                return tuple(tr) + tuple(m1_) + tuple(m2_) + (step_, losses)

            in_specs = (leaf_specs(cfg, fnames) + leaf_specs(cfg, tnames)
                        + leaf_specs(cfg, tnames) + leaf_specs(cfg, tnames)
                        + [spec((), jnp.int32), spec((k, bs, cfg.seq + 1), jnp.int32)])
            inputs = (frozen_io + train_io + m1_io + m2_io
                      + [io_entry("step", (), "s32", "step"),
                         io_entry("tokens", (k, bs, cfg.seq + 1), "s32", "data")])
            outputs = (train_io + m1_io + m2_io
                       + [io_entry("step", (), "s32", "out"),
                          io_entry("losses", (k,), "f32", "out")])
            add(f"train_{cname}_{variant}", train_fn, in_specs, inputs, outputs,
                kind="train_chunk", config=cname, variant=variant,
                chunk_steps=k, batch=bs, lr=OPT.lr)

            def evalf(*leaves, cfg=cfg, v=variant, nf=len(fnames), nt=len(tnames)):
                return M.eval_loss(cfg, v, leaves[:nf], leaves[nf:nf + nt],
                                   leaves[nf + nt])

            add(f"eval_{cname}_{variant}", evalf,
                leaf_specs(cfg, fnames) + leaf_specs(cfg, tnames)
                + [spec((bs, cfg.seq + 1), jnp.int32)],
                frozen_io + train_io
                + [io_entry("tokens", (bs, cfg.seq + 1), "s32", "data")],
                [io_entry("loss", (), "f32", "out")],
                kind="eval", config=cname, variant=variant, batch=bs)

        # serving (fused only; Tier-2 forward)
        def inferf(*leaves, cfg=cfg, nf=len(fnames), nt=len(tnames)):
            return M.infer_step(cfg, "fused", leaves[:nf], leaves[nf:nf + nt],
                                leaves[nf + nt])

        add(f"infer_{cname}_fused", inferf,
            leaf_specs(cfg, fnames) + leaf_specs(cfg, tnames)
            + [spec((bs, cfg.seq), jnp.int32)],
            frozen_io + train_io
            + [io_entry("tokens", (bs, cfg.seq), "s32", "data")],
            [io_entry("logits", (bs, cfg.vocab), "f32", "out")],
            kind="infer", config=cname, variant="fused", batch=bs)

    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    ap.add_argument("--skip-e2e", action="store_true",
                    help="skip the ~100M e2e config (slow to lower)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    arts = build_artifacts(args.only, args.skip_e2e)
    manifest = {
        "schema": 1,
        "generated_unix": int(time.time()),
        "configs": {
            n: {**dataclasses.asdict(c), "scale": c.scale,
                "n_params": c.n_params(),
                "frozen": M.flatten_names_frozen(c),
                "trainable": M.flatten_names_trainable(c),
                "train_batch": TRAIN_SHAPES[n][0],
                "chunk_steps": TRAIN_SHAPES[n][1]}
            for n, c in CONFIGS.items()
        },
        "opt": dataclasses.asdict(OPT),
        "artifacts": {},
    }

    for art in arts:
        t0 = time.time()
        lowered = jax.jit(art.fn).lower(*art.in_specs)
        text = to_hlo_text(lowered)
        fname = f"{art.name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"][art.name] = {
            "file": fname,
            "sha256_16": digest,
            "inputs": art.inputs,
            "outputs": art.outputs,
            "meta": art.meta,
        }
        print(f"  {art.name:36s} {len(text)/1024:9.1f} KiB  "
              f"{time.time() - t0:6.1f}s")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(arts)} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
